//! Versioned binary checkpoints of full run state, with **bit-identical**
//! resume: every `f64` travels as its IEEE-754 bit pattern
//! (little-endian `to_bits`), every RNG as its raw `(state, inc)` pair,
//! so a restored run replays the exact trajectory of an uninterrupted
//! one (`tests/persistence.rs` locks this across all six `AlgSpec`
//! variants and both engines).
//!
//! Layout: 8-byte magic `CQCKPT01`, `u32` format version, then
//! [`RunState`] — iteration, per-worker [`CoreState`]s, medium totals +
//! link-model state, the trace accumulator, and (since version 2) the
//! dynamic-network section: per-worker membership (`active`) and
//! staleness counters (`stale`).  Version-1 checkpoints still decode —
//! they predate churn, so the dynamic section defaults to everyone
//! present with zero staleness.  Checkpoints are O(state), not
//! O(history): the transmission log is folded into its running totals
//! ([`crate::comm::CommLog::restore_totals`]).
//!
//! Writes are atomic (temp file + rename) so a crash mid-checkpoint
//! leaves the previous checkpoint intact.

use crate::comm::LinkState;
use crate::metrics::{Trace, TracePoint};
use crate::protocol::CoreState;
use crate::quant::QuantizerState;
use std::path::Path;

const MAGIC: &[u8; 8] = b"CQCKPT01";
const VERSION: u32 = 2;

/// Everything a resumed engine needs to continue bit-for-bit.
#[derive(Clone, Debug, PartialEq)]
pub struct RunState {
    /// Completed iterations.
    pub iteration: u64,
    /// Durable per-worker state, in worker order.
    pub cores: Vec<CoreState>,
    pub medium: MediumState,
    /// The trace accumulated so far (a resumed run appends to it, so the
    /// final trace equals an uninterrupted run's).
    pub trace: Trace,
    /// Per-worker membership under churn (all `true` on a static graph
    /// and in version-1 checkpoints).
    pub active: Vec<bool>,
    /// Per-worker consecutive-censored-round counters under the
    /// bounded-staleness policy (all zero without one, and in version-1
    /// checkpoints).
    pub stale: Vec<u64>,
}

/// The medium's durable state: checkpointed totals + link-model RNG.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MediumState {
    pub rounds: u64,
    pub total_bits: u64,
    pub total_energy_j: f64,
    pub sim_time_s: f64,
    pub link: LinkState,
}

// ---- encoder ---------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn vec_f64(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f64(x);
        }
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    fn core(&mut self, c: &CoreState) {
        self.vec_f64(&c.theta);
        self.vec_f64(&c.alpha);
        self.vec_f64(&c.hat_self);
        self.u64(c.hat_nbrs.len() as u64);
        for hat in &c.hat_nbrs {
            self.vec_f64(hat);
        }
        self.bool(c.transmitted_once);
        self.vec_f64(&c.nbr_sum);
        self.bool(c.nbr_stale);
        self.vec_f64(&c.dual_delta);
        self.bool(c.dual_stale);
        match &c.quantizer {
            None => self.u8(0),
            Some(q) => {
                self.u8(1);
                match q.prev_radius {
                    None => self.u8(0),
                    Some(r) => {
                        self.u8(1);
                        self.f64(r);
                    }
                }
                self.u32(q.prev_bits);
                self.u128(q.rng_state);
                self.u128(q.rng_inc);
            }
        }
    }
}

/// Serialize a [`RunState`] to the versioned binary format.
pub fn encode(state: &RunState) -> Vec<u8> {
    let mut e = Enc { buf: Vec::new() };
    e.buf.extend_from_slice(MAGIC);
    e.u32(VERSION);
    e.u64(state.iteration);
    e.u64(state.cores.len() as u64);
    for c in &state.cores {
        e.core(c);
    }
    e.u64(state.medium.rounds);
    e.u64(state.medium.total_bits);
    e.f64(state.medium.total_energy_j);
    e.f64(state.medium.sim_time_s);
    match state.medium.link {
        LinkState::Stateless => e.u8(0),
        LinkState::Rng { state: s, inc } => {
            e.u8(1);
            e.u128(s);
            e.u128(inc);
        }
    }
    e.str(&state.trace.algorithm);
    e.str(&state.trace.dataset);
    e.u64(state.trace.points.len() as u64);
    for p in &state.trace.points {
        e.u64(p.iteration);
        e.f64(p.loss_gap);
        e.f64(p.consensus_gap);
        e.u64(p.cum_rounds);
        e.u64(p.cum_bits);
        e.f64(p.cum_energy_j);
    }
    // version-2 dynamic-network section (last, so a v1 decoder's
    // trailing-bytes check would catch a version mismatch)
    e.u64(state.active.len() as u64);
    for &a in &state.active {
        e.bool(a);
    }
    e.u64(state.stale.len() as u64);
    for &s in &state.stale {
        e.u64(s);
    }
    e.buf
}

/// Serialize a single [`CoreState`] standalone (no magic/version header)
/// — the networked transport ships worker state in registration and
/// clean-shutdown frames using the exact checkpoint layout, so state that
/// crossed the wire is bit-identical to state that crossed a file.
pub fn encode_core(core: &CoreState) -> Vec<u8> {
    let mut e = Enc { buf: Vec::new() };
    e.core(core);
    e.buf
}

/// Parse a [`CoreState`] produced by [`encode_core`]; rejects trailing
/// bytes like the full-checkpoint decoder.
pub fn decode_core(bytes: &[u8]) -> Result<CoreState, String> {
    let mut d = Dec { buf: bytes, pos: 0 };
    let core = d.core()?;
    if d.pos != bytes.len() {
        return Err(format!("core state corrupt: {} trailing bytes", bytes.len() - d.pos));
    }
    Ok(core)
}

// ---- decoder ---------------------------------------------------------

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "checkpoint truncated at byte {} (wanted {n} more of {})",
                self.pos,
                self.buf.len()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn u128(&mut self) -> Result<u128, String> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn len(&mut self, what: &str) -> Result<usize, String> {
        let n = self.u64()?;
        // a corrupt length must not trigger a huge allocation
        if n > (self.buf.len() as u64) {
            return Err(format!("checkpoint corrupt: {what} length {n} exceeds file size"));
        }
        Ok(n as usize)
    }
    fn vec_f64(&mut self, what: &str) -> Result<Vec<f64>, String> {
        let n = self.len(what)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }
    fn str(&mut self, what: &str) -> Result<String, String> {
        let n = self.len(what)?;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| format!("checkpoint corrupt: {what} is not UTF-8"))
    }
    fn bool(&mut self, what: &str) -> Result<bool, String> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(format!("checkpoint corrupt: {what} flag byte {b}")),
        }
    }

    fn core(&mut self) -> Result<CoreState, String> {
        let theta = self.vec_f64("theta")?;
        let alpha = self.vec_f64("alpha")?;
        let hat_self = self.vec_f64("hat_self")?;
        let deg = self.len("hat_nbrs")?;
        let mut hat_nbrs = Vec::with_capacity(deg);
        for _ in 0..deg {
            hat_nbrs.push(self.vec_f64("hat_nbr")?);
        }
        let transmitted_once = self.bool("transmitted_once")?;
        let nbr_sum = self.vec_f64("nbr_sum")?;
        let nbr_stale = self.bool("nbr_stale")?;
        let dual_delta = self.vec_f64("dual_delta")?;
        let dual_stale = self.bool("dual_stale")?;
        let quantizer = match self.u8()? {
            0 => None,
            1 => {
                let prev_radius = match self.u8()? {
                    0 => None,
                    1 => Some(self.f64()?),
                    b => return Err(format!("checkpoint corrupt: radius flag byte {b}")),
                };
                Some(QuantizerState {
                    prev_radius,
                    prev_bits: self.u32()?,
                    rng_state: self.u128()?,
                    rng_inc: self.u128()?,
                })
            }
            b => return Err(format!("checkpoint corrupt: quantizer flag byte {b}")),
        };
        Ok(CoreState {
            theta,
            alpha,
            hat_self,
            hat_nbrs,
            transmitted_once,
            nbr_sum,
            nbr_stale,
            dual_delta,
            dual_stale,
            quantizer,
        })
    }
}

/// Parse a checkpoint produced by [`encode`].
pub fn decode(bytes: &[u8]) -> Result<RunState, String> {
    let mut d = Dec { buf: bytes, pos: 0 };
    if d.take(8)? != MAGIC {
        return Err("not a checkpoint file (bad magic)".into());
    }
    let version = d.u32()?;
    if version == 0 || version > VERSION {
        return Err(format!("unsupported checkpoint version {version} (expected 1..={VERSION})"));
    }
    let iteration = d.u64()?;
    let n = d.len("cores")?;
    let mut cores = Vec::with_capacity(n);
    for _ in 0..n {
        cores.push(d.core()?);
    }
    let medium = MediumState {
        rounds: d.u64()?,
        total_bits: d.u64()?,
        total_energy_j: d.f64()?,
        sim_time_s: d.f64()?,
        link: match d.u8()? {
            0 => LinkState::Stateless,
            1 => LinkState::Rng { state: d.u128()?, inc: d.u128()? },
            b => return Err(format!("checkpoint corrupt: link flag byte {b}")),
        },
    };
    let algorithm = d.str("algorithm")?;
    let dataset = d.str("dataset")?;
    let mut trace = Trace::new(&algorithm, &dataset);
    let npts = d.len("trace points")?;
    for _ in 0..npts {
        trace.push(TracePoint {
            iteration: d.u64()?,
            loss_gap: d.f64()?,
            consensus_gap: d.f64()?,
            cum_rounds: d.u64()?,
            cum_bits: d.u64()?,
            cum_energy_j: d.f64()?,
        });
    }
    let (active, stale) = if version >= 2 {
        let na = d.len("active")?;
        let mut active = Vec::with_capacity(na);
        for _ in 0..na {
            active.push(d.bool("active")?);
        }
        let ns = d.len("stale")?;
        let mut stale = Vec::with_capacity(ns);
        for _ in 0..ns {
            stale.push(d.u64()?);
        }
        (active, stale)
    } else {
        // v1 predates dynamic networks: everyone present, nothing stale
        (vec![true; n], vec![0u64; n])
    };
    if d.pos != bytes.len() {
        return Err(format!("checkpoint corrupt: {} trailing bytes", bytes.len() - d.pos));
    }
    Ok(RunState { iteration, cores, medium, trace, active, stale })
}

/// Write a checkpoint atomically: temp file in the same directory, then
/// rename over the target, so a crash never clobbers the previous one.
pub fn save_atomic(state: &RunState, path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, encode(state))?;
    std::fs::rename(&tmp, path)
}

/// Load and parse a checkpoint.
pub fn load(path: &Path) -> std::io::Result<RunState> {
    let bytes = std::fs::read(path)?;
    decode(&bytes).map_err(|e| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, format!("{}: {e}", path.display()))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> RunState {
        let mut trace = Trace::new("cq_ggadmm", "synthetic");
        trace.push(TracePoint {
            iteration: 2,
            loss_gap: 0.125,
            consensus_gap: -0.0, // signed zero must survive (to_bits)
            cum_rounds: 7,
            cum_bits: 1234,
            cum_energy_j: 3.5e-4,
        });
        RunState {
            iteration: 2,
            cores: vec![
                CoreState {
                    theta: vec![1.0, f64::MIN_POSITIVE, -3.25],
                    alpha: vec![0.0, -0.5, 1e300],
                    hat_self: vec![0.25; 3],
                    hat_nbrs: vec![vec![0.5; 3], vec![-0.5; 3]],
                    transmitted_once: true,
                    nbr_sum: vec![0.0; 3],
                    nbr_stale: true,
                    dual_delta: vec![1.5; 3],
                    dual_stale: false,
                    quantizer: Some(QuantizerState {
                        prev_radius: Some(0.75),
                        prev_bits: 5,
                        rng_state: u128::MAX - 17,
                        rng_inc: 12345,
                    }),
                },
                CoreState {
                    theta: vec![2.0; 3],
                    alpha: vec![0.0; 3],
                    hat_self: vec![0.0; 3],
                    hat_nbrs: vec![vec![0.0; 3]],
                    transmitted_once: false,
                    nbr_sum: vec![0.0; 3],
                    nbr_stale: false,
                    dual_delta: vec![0.0; 3],
                    dual_stale: true,
                    quantizer: None,
                },
            ],
            medium: MediumState {
                rounds: 7,
                total_bits: 1234,
                total_energy_j: 3.5e-4,
                sim_time_s: 0.007,
                link: LinkState::Rng { state: 42, inc: 99 },
            },
            trace,
            active: vec![true, false],
            stale: vec![3, 0],
        }
    }

    #[test]
    fn round_trip_is_exact() {
        let s = sample_state();
        let decoded = decode(&encode(&s)).expect("decode");
        assert_eq!(decoded, s);
        // signed zero specifically: PartialEq on f64 treats -0.0 == 0.0,
        // so check the bit pattern directly
        assert_eq!(
            decoded.trace.points[0].consensus_gap.to_bits(),
            (-0.0f64).to_bits()
        );
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let mut bytes = encode(&sample_state());
        assert!(decode(&bytes[..4]).is_err(), "truncated magic");
        bytes[0] ^= 0xFF;
        assert!(decode(&bytes).unwrap_err().contains("magic"));
        bytes[0] ^= 0xFF;
        bytes[8] = 99; // version
        assert!(decode(&bytes).unwrap_err().contains("version"));
    }

    #[test]
    fn decodes_version_1_with_default_dynamic_section() {
        let s = sample_state();
        let mut bytes = encode(&s);
        // strip the trailing dynamic section and stamp version 1: the
        // section is (len + n bools) + (len + n u64s) at the very end
        let n = s.cores.len();
        bytes.truncate(bytes.len() - (8 + n) - (8 + 8 * n));
        bytes[8..12].copy_from_slice(&1u32.to_le_bytes());
        let decoded = decode(&bytes).expect("v1 checkpoint must decode");
        assert_eq!(decoded.active, vec![true; n]);
        assert_eq!(decoded.stale, vec![0u64; n]);
        assert_eq!(decoded.cores, s.cores);
        assert_eq!(decoded.medium, s.medium);
    }

    #[test]
    fn rejects_truncation_and_trailing_garbage() {
        let bytes = encode(&sample_state());
        assert!(decode(&bytes[..bytes.len() - 1]).unwrap_err().contains("truncated"));
        let mut longer = bytes.clone();
        longer.push(0);
        assert!(decode(&longer).unwrap_err().contains("trailing"));
    }

    #[test]
    fn core_round_trip_standalone() {
        for core in sample_state().cores {
            let bytes = encode_core(&core);
            assert_eq!(decode_core(&bytes).expect("decode core"), core);
            let mut longer = bytes.clone();
            longer.push(7);
            assert!(decode_core(&longer).unwrap_err().contains("trailing"));
            assert!(decode_core(&bytes[..bytes.len() - 1]).unwrap_err().contains("truncated"));
        }
    }

    #[test]
    fn save_atomic_then_load() {
        let dir = std::env::temp_dir().join(format!("cq_ckpt_test_{}", std::process::id()));
        let path = dir.join("checkpoint.bin");
        let s = sample_state();
        save_atomic(&s, &path).expect("save");
        assert_eq!(load(&path).expect("load"), s);
        // a second save replaces atomically
        save_atomic(&s, &path).expect("resave");
        assert_eq!(load(&path).expect("reload"), s);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
