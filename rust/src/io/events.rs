//! Streaming run observability: a JSONL event log written incrementally
//! by both engines through one sink trait, so `tools/tail_events.py` and
//! future dashboards can tail live runs instead of waiting for process
//! exit.
//!
//! Schema (one JSON object per line, `"event"` discriminates):
//! * `run_start` — `schema`, `algorithm`, `dataset`, `workers`, `d`,
//!   `seed`; always the first line of a fresh log.
//! * `record` — emitted at the engine's `record_every` cadence:
//!   `iteration`, `loss_gap`, `consensus_gap`, `cum_rounds`, `cum_bits`,
//!   `cum_energy_j`, `sim_time_s`, plus interval aggregates since the
//!   previous record: `committed` (broadcast attempts on the air,
//!   including erasure-dropped ones — the medium charges them),
//!   `censored` (gate-suppressed attempts), and `worker_bits` (sparse
//!   `[worker, bits]` pairs in ascending worker order).  Multi-block
//!   runs (schema ≥ 3) additionally carry `cum_block_bits` — the
//!   cumulative bits spent per parameter block, summing to `cum_bits`;
//!   single-block runs omit the key entirely.
//! * `checkpoint` — `iteration`, `path`; a durable checkpoint landed.
//! * `worker_leave` / `worker_join` (schema ≥ 2) — `iteration`,
//!   `worker`; a churn event applied at the start of that iteration.
//! * `worker_connect` / `worker_disconnect` (schema ≥ 2) — `iteration`,
//!   `worker`; a networked worker's socket registered with / dropped
//!   from the serve loop ([`crate::net`]).  Transport-level membership:
//!   a disconnect is followed by a `worker_leave` when the run degrades,
//!   a reconnect by a `worker_join` when it rejoins.
//! * `stale_refresh` (schema ≥ 2) — `iteration`, `worker`, `staleness`;
//!   the bounded-staleness policy force-refreshed a worker whose
//!   broadcast had been censored or lost for `staleness` rounds.
//!
//! Schema history: v1 derived the `censored` count as
//! `workers x interval - committed`, which over-counts when churned-out
//! workers skip the gate entirely; v2 counts actual gate entries
//! ([`EventRecorder::note_attempt`]) — identical to v1 on a static
//! graph.  v3 adds the optional `cum_block_bits` record field for
//! multi-block parameterizations; a single-block v3 stream is
//! line-identical to v2 except for the stamped version.  Cumulative
//! fields restart from checkpointed totals on resume, so a resumed log
//! concatenated after the original's prefix validates identically to an
//! uninterrupted one.

use super::Json;
use crate::comm::CommLog;
use crate::metrics::TracePoint;
use std::io::Write as _;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Current event-schema version (the `schema` field of `run_start`).
pub const EVENT_SCHEMA_VERSION: u64 = 3;

/// Where events go.  One line per event; implementations must keep lines
/// tailable (flush per event or equivalent).
pub trait EventSink: Send {
    fn emit(&mut self, event: &Json) -> std::io::Result<()>;
}

/// JSONL file sink; flushes after every event so `tail -f` (and the CI
/// validator) see complete lines while the run is live.
pub struct JsonlSink {
    file: std::io::BufWriter<std::fs::File>,
}

impl JsonlSink {
    /// Create (truncate) the log at `path`.
    pub fn create(path: &Path) -> std::io::Result<JsonlSink> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(JsonlSink { file: std::io::BufWriter::new(std::fs::File::create(path)?) })
    }

    /// Append to an existing log (resume).
    pub fn append(path: &Path) -> std::io::Result<JsonlSink> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JsonlSink { file: std::io::BufWriter::new(file) })
    }
}

impl EventSink for JsonlSink {
    fn emit(&mut self, event: &Json) -> std::io::Result<()> {
        self.file.write_all(event.render().as_bytes())?;
        self.file.write_all(b"\n")?;
        self.file.flush()
    }
}

/// In-memory sink for tests: rendered lines behind a shared handle.
#[derive(Clone, Default)]
pub struct MemorySink {
    lines: Arc<Mutex<Vec<String>>>,
}

impl MemorySink {
    pub fn new() -> MemorySink {
        MemorySink::default()
    }

    pub fn lines(&self) -> Vec<String> {
        self.lines.lock().unwrap().clone()
    }
}

impl EventSink for MemorySink {
    fn emit(&mut self, event: &Json) -> std::io::Result<()> {
        self.lines.lock().unwrap().push(event.render());
        Ok(())
    }
}

/// Shared event emission logic of both engines: turns trace points plus
/// the transmission log into `record` events with per-interval worker
/// aggregates.  The recorder watches the [`CommLog`]'s transmission list
/// incrementally (`seen_tx`), so emission is O(interval), not O(history).
pub struct EventRecorder {
    sink: Box<dyn EventSink>,
    /// Transmissions already folded into an emitted record.
    seen_tx: usize,
    /// Iteration of the last emitted record (= resume point's iteration
    /// after a restore).
    last_iter: u64,
    /// Worker count (sizes the per-worker bit aggregates).
    workers: usize,
    /// Broadcast-gate entries since the last record (engines call
    /// [`EventRecorder::note_attempt`] once per gate entry; censored =
    /// attempts - committed).
    attempts: u64,
}

impl EventRecorder {
    pub fn new(sink: Box<dyn EventSink>, workers: usize) -> EventRecorder {
        EventRecorder { sink, seen_tx: 0, last_iter: 0, workers, attempts: 0 }
    }

    /// Rebase after a restore: interval accounting restarts at
    /// `iteration` and the (cleared) transmission log is re-watched from
    /// the top.
    pub fn rebase(&mut self, iteration: u64) {
        self.seen_tx = 0;
        self.last_iter = iteration;
        self.attempts = 0;
    }

    /// One broadcast-gate entry (called by the engines for every worker
    /// that reaches the censor gate, committed or not).  On a static
    /// graph this is `workers` per iteration — the v1 closed form;
    /// under churn, absent and degree-0 workers never reach the gate.
    pub fn note_attempt(&mut self) {
        self.attempts += 1;
    }

    fn emit(&mut self, event: Json) {
        self.sink.emit(&event).expect("event sink write failed");
    }

    /// First line of a fresh log.
    pub fn run_start(
        &mut self,
        algorithm: &str,
        dataset: &str,
        workers: usize,
        d: usize,
        seed: u64,
    ) {
        self.emit(Json::Obj(vec![
            ("event".into(), Json::Str("run_start".into())),
            ("schema".into(), Json::Num(EVENT_SCHEMA_VERSION as f64)),
            ("algorithm".into(), Json::Str(algorithm.into())),
            ("dataset".into(), Json::Str(dataset.into())),
            ("workers".into(), Json::Num(workers as f64)),
            ("d".into(), Json::Num(d as f64)),
            ("seed".into(), Json::Num(seed as f64)),
        ]));
    }

    /// One sampled point: cumulative metrics from the trace point, plus
    /// interval aggregates from the unseen tail of the transmission log.
    pub fn record(&mut self, p: &TracePoint, log: &CommLog, sim_time_s: f64) {
        let mut bits_by_worker = vec![0u64; self.workers];
        let fresh = &log.transmissions[self.seen_tx..];
        for t in fresh {
            bits_by_worker[t.worker] += t.payload_bits;
        }
        let committed = fresh.len() as u64;
        self.seen_tx = log.transmissions.len();
        // censored = gate entries that did not go on the air
        let attempts = std::mem::take(&mut self.attempts);
        self.last_iter = p.iteration;
        let censored = attempts.saturating_sub(committed);
        let worker_bits = bits_by_worker
            .iter()
            .enumerate()
            .filter(|(_, &b)| b > 0)
            .map(|(w, &b)| Json::Arr(vec![Json::Num(w as f64), Json::Num(b as f64)]))
            .collect();
        let mut event = Json::Obj(vec![
            ("event".into(), Json::Str("record".into())),
            ("iteration".into(), Json::Num(p.iteration as f64)),
            ("loss_gap".into(), Json::Num(p.loss_gap)),
            ("consensus_gap".into(), Json::Num(p.consensus_gap)),
            ("cum_rounds".into(), Json::Num(p.cum_rounds as f64)),
            ("cum_bits".into(), Json::Num(p.cum_bits as f64)),
            ("cum_energy_j".into(), Json::Num(p.cum_energy_j)),
            ("sim_time_s".into(), Json::Num(sim_time_s)),
            ("committed".into(), Json::Num(committed as f64)),
            ("censored".into(), Json::Num(censored as f64)),
            ("worker_bits".into(), Json::Arr(worker_bits)),
        ]);
        if !log.block_bits.is_empty() {
            // multi-block ledger: cumulative per-block bits (sums to
            // cum_bits) — the bit-allocation ablation's observable
            let Json::Obj(fields) = &mut event else { unreachable!() };
            fields.push((
                "cum_block_bits".into(),
                Json::Arr(log.block_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
            ));
        }
        self.emit(event);
    }

    /// A durable checkpoint landed at `path`.
    pub fn checkpoint(&mut self, iteration: u64, path: &Path) {
        self.emit(Json::Obj(vec![
            ("event".into(), Json::Str("checkpoint".into())),
            ("iteration".into(), Json::Num(iteration as f64)),
            ("path".into(), Json::Str(path.display().to_string())),
        ]));
    }

    /// A churn event detached `worker` at the start of `iteration`.
    pub fn worker_leave(&mut self, iteration: u64, worker: usize) {
        self.membership("worker_leave", iteration, worker);
    }

    /// A churn event re-attached `worker` at the start of `iteration`.
    pub fn worker_join(&mut self, iteration: u64, worker: usize) {
        self.membership("worker_join", iteration, worker);
    }

    /// A networked worker's socket registered with the serve loop.
    pub fn worker_connect(&mut self, iteration: u64, worker: usize) {
        self.membership("worker_connect", iteration, worker);
    }

    /// A networked worker's socket dropped from the serve loop.
    pub fn worker_disconnect(&mut self, iteration: u64, worker: usize) {
        self.membership("worker_disconnect", iteration, worker);
    }

    fn membership(&mut self, event: &str, iteration: u64, worker: usize) {
        self.emit(Json::Obj(vec![
            ("event".into(), Json::Str(event.into())),
            ("iteration".into(), Json::Num(iteration as f64)),
            ("worker".into(), Json::Num(worker as f64)),
        ]));
    }

    /// The bounded-staleness policy force-refreshed `worker` during
    /// `iteration` after `staleness` consecutive stale rounds.
    pub fn stale_refresh(&mut self, iteration: u64, worker: usize, staleness: u64) {
        self.emit(Json::Obj(vec![
            ("event".into(), Json::Str("stale_refresh".into())),
            ("iteration".into(), Json::Num(iteration as f64)),
            ("worker".into(), Json::Num(worker as f64)),
            ("staleness".into(), Json::Num(staleness as f64)),
        ]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Transmission;

    fn tx(worker: usize, iteration: u64, bits: u64) -> Transmission {
        Transmission { worker, iteration, payload_bits: bits, distance_m: 1.0, energy_j: 0.0 }
    }

    fn point(iteration: u64) -> TracePoint {
        TracePoint {
            iteration,
            loss_gap: 0.5,
            consensus_gap: 0.25,
            cum_rounds: 3,
            cum_bits: 300,
            cum_energy_j: 1e-3,
        }
    }

    #[test]
    fn record_aggregates_interval_per_worker() {
        let sink = MemorySink::new();
        let mut rec = EventRecorder::new(Box::new(sink.clone()), 3);
        let mut log = CommLog::default();
        // 3 workers x 2 iterations reach the gate, 3 go on the air
        for _ in 0..6 {
            rec.note_attempt();
        }
        log.record(tx(0, 0, 100));
        log.record(tx(2, 0, 100));
        log.record(tx(0, 1, 100));
        rec.record(&point(2), &log, 0.5);
        let lines = sink.lines();
        assert_eq!(lines.len(), 1);
        let l = &lines[0];
        assert!(l.contains(r#""event":"record""#), "{l}");
        assert!(l.contains(r#""committed":3"#), "{l}");
        assert!(l.contains(r#""censored":3"#), "{l}");
        assert!(l.contains(r#""worker_bits":[[0,200],[2,100]]"#), "{l}");
        // the next record only sees fresh attempts and transmissions
        for _ in 0..3 {
            rec.note_attempt();
        }
        log.record(tx(1, 2, 40));
        rec.record(&point(3), &log, 0.6);
        let l2 = &sink.lines()[1];
        assert!(l2.contains(r#""committed":1"#), "{l2}");
        assert!(l2.contains(r#""censored":2"#), "{l2}");
        assert!(l2.contains(r#""worker_bits":[[1,40]]"#), "{l2}");
    }

    #[test]
    fn multi_block_records_carry_cumulative_block_bits() {
        let sink = MemorySink::new();
        let mut rec = EventRecorder::new(Box::new(sink.clone()), 2);
        let mut log = CommLog::default();
        rec.note_attempt();
        log.record(tx(0, 0, 100));
        // flat ledger: the key is absent
        rec.record(&point(1), &log, 0.1);
        assert!(!sink.lines()[0].contains("cum_block_bits"), "{}", sink.lines()[0]);
        // block ledger: cumulative per-block totals ride along
        log.record_block_bits(&[96, 4]);
        log.record_block_bits(&[0, 4]);
        rec.note_attempt();
        log.record(tx(1, 1, 100));
        rec.record(&point(2), &log, 0.2);
        let l = &sink.lines()[1];
        assert!(l.contains(r#""cum_block_bits":[96,8]"#), "{l}");
    }

    #[test]
    fn rebase_restarts_interval_accounting() {
        let sink = MemorySink::new();
        let mut rec = EventRecorder::new(Box::new(sink.clone()), 2);
        let mut log = CommLog::default();
        log.restore_totals(10, 1000, 1e-2);
        rec.note_attempt(); // stale pre-restore attempt must be dropped
        rec.rebase(5);
        rec.note_attempt();
        rec.note_attempt();
        log.record(tx(0, 5, 64));
        rec.record(&point(6), &log, 1.0);
        let l = &sink.lines()[0];
        assert!(l.contains(r#""committed":1"#), "{l}");
        assert!(l.contains(r#""censored":1"#), "{l}");
    }

    #[test]
    fn dynamic_network_events_render() {
        let sink = MemorySink::new();
        let mut rec = EventRecorder::new(Box::new(sink.clone()), 2);
        rec.worker_leave(3, 1);
        rec.worker_join(7, 1);
        rec.stale_refresh(5, 0, 4);
        rec.worker_connect(0, 1);
        rec.worker_disconnect(9, 1);
        let lines = sink.lines();
        assert!(lines[0].contains(r#""event":"worker_leave""#), "{}", lines[0]);
        assert!(lines[0].contains(r#""iteration":3"#), "{}", lines[0]);
        assert!(lines[0].contains(r#""worker":1"#), "{}", lines[0]);
        assert!(lines[1].contains(r#""event":"worker_join""#), "{}", lines[1]);
        assert!(lines[2].contains(r#""event":"stale_refresh""#), "{}", lines[2]);
        assert!(lines[2].contains(r#""staleness":4"#), "{}", lines[2]);
        assert!(lines[3].contains(r#""event":"worker_connect""#), "{}", lines[3]);
        assert!(lines[4].contains(r#""event":"worker_disconnect""#), "{}", lines[4]);
        assert!(lines[4].contains(r#""iteration":9"#), "{}", lines[4]);
    }
}
