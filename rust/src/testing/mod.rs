//! First-party testing utilities.
//!
//! The offline sandbox has no `proptest`, so [`prop`] provides a minimal
//! property-based testing harness with the same workflow: generators over
//! a seeded RNG, many random cases per property, and a reproducible
//! counterexample report (`PROP_SEED` env var reruns a failing seed).

pub mod prop;
