//! Minimal property-based testing harness (proptest substitute).
//!
//! ```no_run
//! // (no_run: rustdoc test binaries miss the xla rpath in this sandbox)
//! use cq_ggadmm::testing::prop::{check, Gen};
//!
//! check("abs is non-negative", 200, |g| {
//!     let x = g.f64_in(-1e6, 1e6);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```
//!
//! Failures print the case index and the per-case seed; re-run a single
//! case with `PROP_SEED=<seed>` to reproduce deterministically.

use crate::util::rng::Pcg64;

/// Per-case generator handed to properties.
pub struct Gen {
    rng: Pcg64,
    /// Seed that reproduces this exact case.
    pub seed: u64,
}

impl Gen {
    /// Raw u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Vector of normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        self.rng.normal_vec(n)
    }

    /// Vector of uniforms in `[lo, hi)`.
    pub fn uniform_vec_in(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.uniform_in(lo, hi)).collect()
    }

    /// Bernoulli.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bernoulli(p)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len() as u64) as usize]
    }

    /// Access the underlying RNG (for domain-specific generators).
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `cases` random cases of `property`.  Panics (failing the enclosing
/// `#[test]`) with a reproduction seed on the first failing case.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u32, property: F) {
    // Fixed master seed by default => CI-stable; override for exploration.
    let master = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    let case_seeds: Vec<u64> = match master {
        Some(s) => vec![s],
        None => {
            let mut root = Pcg64::new(0xC0FFEE ^ fnv(name));
            (0..cases).map(|_| root.next_u64()).collect()
        }
    };
    for (i, seed) in case_seeds.iter().enumerate() {
        let mut g = Gen { rng: Pcg64::new(*seed), seed: *seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut g);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {i}/{cases}: {msg}\n\
                 reproduce with: PROP_SEED={seed} cargo test"
            );
        }
    }
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum of squares non-negative", 100, |g| {
            let n = g.usize_in(0, 10);
            let v = g.normal_vec(n);
            assert!(v.iter().map(|x| x * x).sum::<f64>() >= 0.0);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let res = std::panic::catch_unwind(|| {
            check("always fails", 5, |_| panic!("boom"));
        });
        let err = res.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| format!("{err:?}"));
        assert!(msg.contains("PROP_SEED="), "missing repro seed: {msg}");
    }

    #[test]
    fn gen_ranges_respected() {
        check("ranges", 100, |g| {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&f));
        });
    }
}
