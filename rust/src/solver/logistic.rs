//! Native damped-Newton solver for the logistic-regression subproblem.
//!
//! `f_n(theta) = (1/s) sum_i log(1 + exp(-y_i x_i^T theta))
//!               + (mu0/2) ||theta||^2`
//!
//! The subproblem adds `<theta, lin>` and `(rho d_n / 2)||theta||^2`; it is
//! `(mu0 + rho d_n)`-strongly convex, so Newton with an Armijo backtrack
//! converges quadratically.  This mirrors the fixed-budget Newton+CG AOT
//! artifact (`logistic_newton`); the native version iterates to a gradient
//! tolerance instead of a fixed budget (both land on the same minimizer —
//! the differential tests in `tests/` check agreement to ~1e-4).

use super::SubproblemSolver;
use crate::linalg::{Cholesky, Mat};

/// Newton solver for one worker's logistic shard.
pub struct LogisticSolver {
    x: Mat,
    y: Vec<f64>,
    mu0: f64,
    rho: f64,
    rho_dn: f64,
    inv_s: f64,
    /// gradient-norm stopping tolerance
    tol: f64,
    max_newton: usize,
}

impl LogisticSolver {
    pub fn new(x: Mat, y: Vec<f64>, mu0: f64, rho: f64, degree: usize) -> LogisticSolver {
        assert_eq!(x.rows(), y.len());
        assert!(!y.is_empty());
        let inv_s = 1.0 / y.len() as f64;
        LogisticSolver {
            x,
            y,
            mu0,
            rho,
            rho_dn: rho * degree as f64,
            inv_s,
            tol: 1e-10,
            max_newton: 50,
        }
    }

    /// Per-sample probabilities `p_i = sigmoid(-y_i x_i^T theta)`.
    fn probs(&self, theta: &[f64]) -> Vec<f64> {
        (0..self.y.len())
            .map(|i| {
                let z = self.y[i] * crate::util::dot(self.x.row(i), theta);
                1.0 / (1.0 + z.exp())
            })
            .collect()
    }

    /// Data-term gradient `g = sum -y_i p_i x_i` from precomputed probs.
    fn grad_data(&self, probs: &[f64]) -> Vec<f64> {
        let d = self.x.cols();
        let mut g = vec![0.0; d];
        for (i, &p) in probs.iter().enumerate() {
            let gscale = -self.y[i] * p;
            let row = self.x.row(i);
            for a in 0..d {
                g[a] += gscale * row[a];
            }
        }
        g
    }

    /// Data-term Hessian `H = sum w_i x_i x_i^T` (upper triangle assembled
    /// through contiguous row slices, then mirrored — the assembly is the
    /// per-Newton-step hot spot; see EXPERIMENTS.md §Perf).
    fn hess_data(&self, probs: &[f64]) -> Mat {
        let d = self.x.cols();
        let mut h = Mat::zeros(d, d);
        for (i, &p) in probs.iter().enumerate() {
            let w = p * (1.0 - p);
            if w <= 0.0 {
                continue;
            }
            for a in 0..d {
                let wa = w * self.x.row(i)[a];
                if wa == 0.0 {
                    continue;
                }
                let (row, hrow) = (self.x.row(i), h.row_mut(a));
                for b in a..d {
                    hrow[b] += wa * row[b];
                }
            }
        }
        for a in 0..d {
            for b in 0..a {
                h[(a, b)] = h[(b, a)];
            }
        }
        h
    }

    /// Combined data gradient + Hessian (tests / diagnostics).
    #[cfg_attr(not(test), allow(dead_code))]
    fn grad_hess_data(&self, theta: &[f64]) -> (Vec<f64>, Mat) {
        let probs = self.probs(theta);
        (self.grad_data(&probs), self.hess_data(&probs))
    }

    /// Subproblem objective (for the Armijo line search).
    fn sub_objective(&self, theta: &[f64], lin: &[f64]) -> f64 {
        self.loss(theta)
            + crate::util::dot(theta, lin)
            + 0.5 * self.rho_dn * crate::util::dot(theta, theta)
    }
}

impl SubproblemSolver for LogisticSolver {
    fn update(&mut self, alpha: &[f64], nbr_sum: &[f64], warm: &[f64]) -> Vec<f64> {
        let d = warm.len();
        assert_eq!(alpha.len(), d);
        // linear term of eq. (22): lin = alpha_n - rho * sum theta_hat_m
        let lin: Vec<f64> = alpha
            .iter()
            .zip(nbr_sum)
            .map(|(a, n)| a - self.rho * n)
            .collect();
        let mut theta = warm.to_vec();
        for _ in 0..self.max_newton {
            // gradient first: with ADMM warm starts most calls converge in
            // one step, so skipping the Hessian assembly on the final
            // (already-converged) check saves ~half the work (§Perf)
            let probs = self.probs(&theta);
            let g_data = self.grad_data(&probs);
            let mut grad = vec![0.0; d];
            for i in 0..d {
                grad[i] = self.inv_s * g_data[i]
                    + self.mu0 * theta[i]
                    + lin[i]
                    + self.rho_dn * theta[i];
            }
            let gnorm = crate::util::norm2(&grad);
            if gnorm < self.tol * (1.0 + crate::util::norm2(&theta)) {
                break;
            }
            let h = self
                .hess_data(&probs)
                .scale(self.inv_s)
                .add_diag(self.mu0 + self.rho_dn);
            let step = Cholesky::new(&h)
                .expect("subproblem Hessian is SPD")
                .solve(&grad);
            // Armijo backtracking on the subproblem objective
            let f0 = self.sub_objective(&theta, &lin);
            let slope = crate::util::dot(&grad, &step);
            let mut t = 1.0;
            loop {
                let cand: Vec<f64> = theta
                    .iter()
                    .zip(&step)
                    .map(|(th, st)| th - t * st)
                    .collect();
                if self.sub_objective(&cand, &lin) <= f0 - 1e-4 * t * slope || t < 1e-8 {
                    theta = cand;
                    break;
                }
                t *= 0.5;
            }
        }
        theta
    }

    fn loss(&self, theta: &[f64]) -> f64 {
        let s = self.y.len();
        let mut acc = 0.0;
        for i in 0..s {
            let z = self.y[i] * crate::util::dot(self.x.row(i), theta);
            // stable log(1 + exp(-z))
            acc += if z > 0.0 {
                (-z).exp().ln_1p()
            } else {
                -z + z.exp().ln_1p()
            };
        }
        self.inv_s * acc + 0.5 * self.mu0 * crate::util::dot(theta, theta)
    }

    fn d(&self) -> usize {
        self.x.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;
    use crate::util::rng::Pcg64;

    fn random_shard(s: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::zeros(s, d);
        for i in 0..s {
            for j in 0..d {
                x[(i, j)] = rng.normal();
            }
        }
        let y: Vec<f64> = (0..s)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        (x, y)
    }

    #[test]
    fn update_reaches_stationarity() {
        check("logistic update satisfies KKT", 30, |g| {
            let d = g.usize_in(1, 12);
            let s = g.usize_in(4, 50);
            let (x, y) = random_shard(s, d, g.u64());
            let mu0 = g.f64_in(0.01, 0.5);
            let rho = g.f64_in(0.1, 2.0);
            let degree = g.usize_in(1, 4);
            let mut solver = LogisticSolver::new(x.clone(), y.clone(), mu0, rho, degree);
            let alpha = g.normal_vec(d);
            let nbr: Vec<f64> = g.normal_vec(d);
            let theta = solver.update(&alpha, &nbr, &vec![0.0; d]);
            // KKT: (1/s) g_data + mu0 theta + (alpha - rho*nbr) + rho d theta = 0
            let (g_data, _) = solver.grad_hess_data(&theta);
            let mut grad = vec![0.0; d];
            for i in 0..d {
                grad[i] = g_data[i] / s as f64
                    + mu0 * theta[i]
                    + alpha[i]
                    - rho * nbr[i]
                    + rho * degree as f64 * theta[i];
            }
            let gn = crate::util::norm2(&grad);
            assert!(gn < 1e-6, "gnorm={gn}");
        });
    }

    #[test]
    fn loss_stable_for_extreme_margins() {
        let (x, y) = random_shard(10, 3, 1);
        let solver = LogisticSolver::new(x, y, 0.1, 1.0, 1);
        let big = vec![1e3; 3];
        let l = solver.loss(&big);
        assert!(l.is_finite() && l > 0.0);
    }

    #[test]
    fn warm_start_converges_same_point() {
        let (x, y) = random_shard(30, 5, 2);
        let mut solver = LogisticSolver::new(x, y, 0.05, 0.5, 2);
        let alpha = vec![0.1; 5];
        let nbr = vec![0.2; 5];
        let cold = solver.update(&alpha, &nbr, &vec![0.0; 5]);
        let warm = solver.update(&alpha, &nbr, &cold);
        for (a, b) in cold.iter().zip(&warm) {
            assert!((a - b).abs() < 1e-7);
        }
    }
}
