//! Native damped-Newton solver for the logistic-regression subproblem.
//!
//! `f_n(theta) = (1/s) sum_i log(1 + exp(-y_i x_i^T theta))
//!               + (mu0/2) ||theta||^2`
//!
//! The subproblem adds `<theta, lin>` and `(rho d_n / 2)||theta||^2`; it is
//! `(mu0 + rho d_n)`-strongly convex, so Newton with an Armijo backtrack
//! converges quadratically.  This mirrors the fixed-budget Newton+CG AOT
//! artifact (`logistic_newton`); the native version iterates to a gradient
//! tolerance instead of a fixed budget (both land on the same minimizer —
//! the differential tests in `tests/` check agreement to ~1e-4).
//!
//! Perf (the fused Newton kernel; see EXPERIMENTS.md §Perf):
//! * construction borrows the worker's shard through a shared
//!   [`Arc<Shard>`] (no per-worker copy of `X`/`y`);
//! * every Newton-loop vector, the Hessian and its Cholesky factor live
//!   in persistent scratch ([`Cholesky::factor_into`] reuses the factor
//!   storage), so `update_into` allocates nothing after warmup;
//! * margins `z_i = y_i x_i^T theta` and directional margins come from
//!   one blocked [`crate::linalg::block::matvec_into`] pass each (the
//!   blocked matvec runs on the active kernel tier — SIMD when
//!   available — and is bit-identical to the per-row dot within a
//!   tier); probabilities, Hessian weights and the data gradient follow
//!   in one O(s) / O(s d) sweep; the O(s d^2)
//!   Hessian assembly — the per-step hot spot — runs on the blocked
//!   weighted-Gram kernel (`H_data = X^T diag(w) X` via
//!   [`crate::linalg::block::weighted_gram_into`]: packed panels, 2x2
//!   register tiling, no data-dependent branches), and the Newton system
//!   is factored/solved by the blocked Cholesky;
//! * the Armijo backtrack is evaluated analytically from cached margins
//!   and directional margins `u_i = y_i x_i^T step`: each trial costs
//!   O(s) instead of the former O(s d) objective evaluation, and the
//!   accepted step updates the margins in O(s) as well.

use super::SubproblemSolver;
use crate::data::Shard;
use crate::linalg::{Cholesky, Mat};
use std::sync::Arc;

/// Stable `log(1 + exp(-m))` (same branches as [`LogisticSolver::loss`]).
#[inline]
fn softplus_neg(m: f64) -> f64 {
    if m > 0.0 {
        (-m).exp().ln_1p()
    } else {
        -m + m.exp().ln_1p()
    }
}

/// Newton solver for one worker's logistic shard.
pub struct LogisticSolver {
    /// Shared shard; never copied per worker.
    data: Arc<Shard>,
    mu0: f64,
    rho: f64,
    rho_dn: f64,
    inv_s: f64,
    /// gradient-norm stopping tolerance
    tol: f64,
    max_newton: usize,
    /// persistent scratch: linear term of eq. (22)
    lin: Vec<f64>,
    /// persistent scratch: full subproblem gradient
    grad: Vec<f64>,
    /// persistent scratch: Newton step direction
    step: Vec<f64>,
    /// persistent scratch (len s): margins `z_i = y_i x_i^T theta`
    margins: Vec<f64>,
    /// persistent scratch (len s): probabilities `p_i = sigmoid(-z_i)`
    probs: Vec<f64>,
    /// persistent scratch (len s): directional margins `y_i x_i^T step`
    dir_margins: Vec<f64>,
    /// persistent scratch (len s): Hessian weights `w_i = p_i (1 - p_i)`
    weights: Vec<f64>,
    /// persistent scratch (len s): raw products `x_i^T v` from the
    /// blocked matvec (margins/dir_margins are `y_i *` this; the blocked
    /// matvec is bit-identical to the per-row dot on every kernel tier)
    xv: Vec<f64>,
    /// persistent scratch: subproblem Hessian
    hess: Mat,
    /// persistent panel-packing scratch of the blocked weighted-Gram
    /// Hessian assembly (sized by `weighted_gram_into`)
    pack: Vec<f64>,
    /// persistent factor workspace (refilled via `factor_into`)
    chol: Cholesky,
}

impl LogisticSolver {
    /// Build from a shared shard.
    pub fn from_shard(data: Arc<Shard>, mu0: f64, rho: f64, degree: usize) -> LogisticSolver {
        assert_eq!(data.x.rows(), data.y.len());
        assert!(!data.y.is_empty());
        let s = data.y.len();
        let inv_s = 1.0 / s as f64;
        let d = data.x.cols();
        LogisticSolver {
            data,
            mu0,
            rho,
            rho_dn: rho * degree as f64,
            inv_s,
            tol: 1e-10,
            max_newton: 50,
            lin: vec![0.0; d],
            grad: vec![0.0; d],
            step: vec![0.0; d],
            margins: vec![0.0; s],
            probs: vec![0.0; s],
            dir_margins: vec![0.0; s],
            weights: vec![0.0; s],
            xv: vec![0.0; s],
            hess: Mat::zeros(d, d),
            pack: Vec::new(),
            chol: Cholesky::workspace(d),
        }
    }

    /// Owned-data convenience constructor (tests/benches).
    pub fn new(x: Mat, y: Vec<f64>, mu0: f64, rho: f64, degree: usize) -> LogisticSolver {
        Self::from_shard(Arc::new(Shard { worker: 0, x, y }), mu0, rho, degree)
    }

    /// Per-sample probabilities `p_i = sigmoid(-y_i x_i^T theta)`.
    #[cfg_attr(not(test), allow(dead_code))]
    fn probs(&self, theta: &[f64]) -> Vec<f64> {
        (0..self.data.y.len())
            .map(|i| {
                let z = self.data.y[i] * crate::util::dot(self.data.x.row(i), theta);
                1.0 / (1.0 + z.exp())
            })
            .collect()
    }

    /// Data-term gradient `g = sum -y_i p_i x_i` from precomputed probs.
    #[cfg_attr(not(test), allow(dead_code))]
    fn grad_data(&self, probs: &[f64]) -> Vec<f64> {
        let d = self.data.x.cols();
        let mut g = vec![0.0; d];
        for (i, &p) in probs.iter().enumerate() {
            let gscale = -self.data.y[i] * p;
            let row = self.data.x.row(i);
            for a in 0..d {
                g[a] += gscale * row[a];
            }
        }
        g
    }

    /// Data-term Hessian `H = sum w_i x_i x_i^T` (upper triangle assembled
    /// through contiguous row slices, then mirrored — the assembly is the
    /// per-Newton-step hot spot; see EXPERIMENTS.md §Perf).
    #[cfg_attr(not(test), allow(dead_code))]
    fn hess_data(&self, probs: &[f64]) -> Mat {
        let d = self.data.x.cols();
        let mut h = Mat::zeros(d, d);
        for (i, &p) in probs.iter().enumerate() {
            let w = p * (1.0 - p);
            if w <= 0.0 {
                continue;
            }
            for a in 0..d {
                let wa = w * self.data.x.row(i)[a];
                if wa == 0.0 {
                    continue;
                }
                let (row, hrow) = (self.data.x.row(i), h.row_mut(a));
                for b in a..d {
                    hrow[b] += wa * row[b];
                }
            }
        }
        for a in 0..d {
            for b in 0..a {
                h[(a, b)] = h[(b, a)];
            }
        }
        h
    }

    /// Combined data gradient + Hessian (tests / diagnostics).
    #[cfg_attr(not(test), allow(dead_code))]
    fn grad_hess_data(&self, theta: &[f64]) -> (Vec<f64>, Mat) {
        let probs = self.probs(theta);
        (self.grad_data(&probs), self.hess_data(&probs))
    }
}

impl SubproblemSolver for LogisticSolver {
    fn update_into(&mut self, alpha: &[f64], nbr_sum: &[f64], theta: &mut [f64]) {
        let d = theta.len();
        let s = self.data.y.len();
        assert_eq!(alpha.len(), d);
        assert_eq!(nbr_sum.len(), d);
        // linear term of eq. (22): lin = alpha_n - rho * sum theta_hat_m
        for i in 0..d {
            self.lin[i] = alpha[i] - self.rho * nbr_sum[i];
        }
        // fresh margins for the incoming warm start, via one blocked
        // matvec (bit-identical to the per-row dot formulation on every
        // kernel tier); the Newton loop then maintains them in O(s) per
        // accepted step
        crate::linalg::block::matvec_into(&self.data.x, theta, &mut self.xv);
        for i in 0..s {
            self.margins[i] = self.data.y[i] * self.xv[i];
        }
        for _ in 0..self.max_newton {
            // gradient first: with ADMM warm starts most calls converge in
            // one step, so skipping the Hessian assembly on the final
            // (already-converged) check saves ~half the work (§Perf).
            // One fused pass over the shard: probabilities and Hessian
            // weights from the cached margins + the data gradient into
            // persistent scratch.
            self.grad.iter_mut().for_each(|g| *g = 0.0);
            for i in 0..s {
                let p = 1.0 / (1.0 + self.margins[i].exp());
                self.probs[i] = p;
                self.weights[i] = p * (1.0 - p);
                let gscale = -self.data.y[i] * p;
                crate::util::axpy(&mut self.grad, gscale, self.data.x.row(i));
            }
            for i in 0..d {
                self.grad[i] = self.inv_s * self.grad[i]
                    + self.mu0 * theta[i]
                    + self.lin[i]
                    + self.rho_dn * theta[i];
            }
            let gnorm = crate::util::norm2(&self.grad);
            if gnorm < self.tol * (1.0 + crate::util::norm2(theta)) {
                break;
            }
            // Hessian from the cached weights: H_data = X^T diag(w) X on
            // the blocked weighted-Gram kernel (persistent output + panel
            // scratch, branch-free), then one scale + regularize sweep
            // (weighted_gram_into mirrors, so scaling all entries keeps
            // the matrix exactly symmetric)
            crate::linalg::block::weighted_gram_into(
                &self.data.x,
                &self.weights,
                &mut self.hess,
                &mut self.pack,
            );
            let diag = self.mu0 + self.rho_dn;
            for v in self.hess.data_mut().iter_mut() {
                *v *= self.inv_s;
            }
            for a in 0..d {
                self.hess[(a, a)] += diag;
            }
            assert!(
                self.chol.factor_into(&self.hess),
                "subproblem Hessian is SPD"
            );
            self.chol.solve_into(&self.grad, &mut self.step);
            // directional margins: u_i = y_i x_i^T step, via one blocked
            // matvec; every Armijo trial afterwards is O(s)
            crate::linalg::block::matvec_into(&self.data.x, &self.step, &mut self.xv);
            for i in 0..s {
                self.dir_margins[i] = self.data.y[i] * self.xv[i];
            }
            // Armijo backtracking on the subproblem objective, evaluated
            // analytically: with theta_t = theta - t*step,
            //   obj(t) = (1/s) sum softplus(-(z_i - t u_i))
            //          + <theta, lin> - t <step, lin>
            //          + (mu0 + rho_dn)/2 (||theta||^2 - 2t <theta, step>
            //                              + t^2 ||step||^2)
            let lin_theta = crate::util::dot(theta, &self.lin);
            let lin_step = crate::util::dot(&self.step, &self.lin);
            let quad_theta = crate::util::dot(theta, theta);
            let quad_cross = crate::util::dot(theta, &self.step);
            let quad_step = crate::util::dot(&self.step, &self.step);
            let half_pen = 0.5 * (self.mu0 + self.rho_dn);
            let objective = |t: f64, margins: &[f64], dir: &[f64]| -> f64 {
                let mut acc = 0.0;
                for i in 0..s {
                    acc += softplus_neg(margins[i] - t * dir[i]);
                }
                self.inv_s * acc
                    + (lin_theta - t * lin_step)
                    + half_pen * (quad_theta - 2.0 * t * quad_cross + t * t * quad_step)
            };
            let f0 = objective(0.0, &self.margins, &self.dir_margins);
            let slope = crate::util::dot(&self.grad, &self.step);
            let mut t = 1.0;
            loop {
                let ft = objective(t, &self.margins, &self.dir_margins);
                if ft <= f0 - 1e-4 * t * slope || t < 1e-8 {
                    crate::util::axpy(theta, -t, &self.step);
                    for i in 0..s {
                        self.margins[i] -= t * self.dir_margins[i];
                    }
                    break;
                }
                t *= 0.5;
            }
        }
    }

    fn loss(&self, theta: &[f64]) -> f64 {
        let s = self.data.y.len();
        let mut acc = 0.0;
        for i in 0..s {
            let z = self.data.y[i] * crate::util::dot(self.data.x.row(i), theta);
            acc += softplus_neg(z);
        }
        self.inv_s * acc + 0.5 * self.mu0 * crate::util::dot(theta, theta)
    }

    fn d(&self) -> usize {
        self.data.x.cols()
    }

    fn grad_into(&self, theta: &[f64], out: &mut [f64]) {
        // grad f_n = (1/s) sum -y_i p_i x_i + mu0 theta, row-streamed
        let d = self.data.x.cols();
        assert_eq!(theta.len(), d);
        assert_eq!(out.len(), d);
        for g in out.iter_mut() {
            *g = 0.0;
        }
        for i in 0..self.data.y.len() {
            let z = self.data.y[i] * crate::util::dot(self.data.x.row(i), theta);
            let p = 1.0 / (1.0 + z.exp());
            let gscale = -self.data.y[i] * p;
            crate::util::axpy(out, gscale, self.data.x.row(i));
        }
        for j in 0..d {
            out[j] = self.inv_s * out[j] + self.mu0 * theta[j];
        }
    }

    fn set_degree(&mut self, degree: usize) {
        assert!(degree >= 1, "degree-0 workers are never solved");
        // rho_dn is the only degree-dependent term (gradient, Hessian
        // diagonal and Armijo penalty all read it), so mutating it is
        // bit-identical to constructing at `degree`
        self.rho_dn = self.rho * degree as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;
    use crate::util::rng::Pcg64;

    fn random_shard(s: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::zeros(s, d);
        for i in 0..s {
            for j in 0..d {
                x[(i, j)] = rng.normal();
            }
        }
        let y: Vec<f64> = (0..s)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        (x, y)
    }

    #[test]
    fn update_reaches_stationarity() {
        check("logistic update satisfies KKT", 30, |g| {
            let d = g.usize_in(1, 12);
            let s = g.usize_in(4, 50);
            let (x, y) = random_shard(s, d, g.u64());
            let mu0 = g.f64_in(0.01, 0.5);
            let rho = g.f64_in(0.1, 2.0);
            let degree = g.usize_in(1, 4);
            let mut solver = LogisticSolver::new(x.clone(), y.clone(), mu0, rho, degree);
            let alpha = g.normal_vec(d);
            let nbr: Vec<f64> = g.normal_vec(d);
            let theta = solver.update(&alpha, &nbr, &vec![0.0; d]);
            // KKT: (1/s) g_data + mu0 theta + (alpha - rho*nbr) + rho d theta = 0
            let (g_data, _) = solver.grad_hess_data(&theta);
            let mut grad = vec![0.0; d];
            for i in 0..d {
                grad[i] = g_data[i] / s as f64
                    + mu0 * theta[i]
                    + alpha[i]
                    - rho * nbr[i]
                    + rho * degree as f64 * theta[i];
            }
            let gn = crate::util::norm2(&grad);
            assert!(gn < 1e-6, "gnorm={gn}");
        });
    }

    #[test]
    fn update_into_matches_update() {
        let (x, y) = random_shard(30, 5, 4);
        let mut solver = LogisticSolver::new(x, y, 0.05, 0.5, 2);
        let alpha = vec![0.1; 5];
        let nbr = vec![0.2; 5];
        let via_update = solver.update(&alpha, &nbr, &vec![0.0; 5]);
        let mut theta = vec![0.0; 5];
        solver.update_into(&alpha, &nbr, &mut theta);
        for (a, b) in via_update.iter().zip(&theta) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn set_degree_matches_from_scratch_bit_for_bit() {
        check("set_degree == fresh construction", 20, |g| {
            let d = g.usize_in(1, 8);
            let s = g.usize_in(4, 30);
            let (x, y) = random_shard(s, d, g.u64());
            let mu0 = g.f64_in(0.01, 0.5);
            let rho = g.f64_in(0.1, 2.0);
            let (d_old, d_new) = (g.usize_in(1, 5), g.usize_in(1, 5));
            let mut mutated = LogisticSolver::new(x.clone(), y.clone(), mu0, rho, d_old);
            mutated.set_degree(d_new);
            let mut fresh = LogisticSolver::new(x, y, mu0, rho, d_new);
            let alpha = g.normal_vec(d);
            let nbr = g.normal_vec(d);
            let warm = g.normal_vec(d);
            let a = mutated.update(&alpha, &nbr, &warm);
            let b = fresh.update(&alpha, &nbr, &warm);
            assert_eq!(a, b, "churn re-derivation must be bit-identical");
        });
    }

    #[test]
    fn fused_armijo_matches_explicit_objective() {
        // the analytic line-search objective must agree with literally
        // forming the candidate and evaluating the subproblem objective
        check("analytic Armijo objective", 40, |g| {
            let d = g.usize_in(1, 8);
            let s = g.usize_in(3, 30);
            let (x, y) = random_shard(s, d, g.u64());
            let mu0 = g.f64_in(0.01, 0.5);
            let rho = g.f64_in(0.1, 2.0);
            let rho_dn = rho * 2.0;
            let solver = LogisticSolver::new(x.clone(), y.clone(), mu0, rho, 2);
            let theta = g.normal_vec(d);
            let step = g.normal_vec(d);
            let lin = g.normal_vec(d);
            let t = g.f64_in(0.0, 1.0);
            // analytic path (mirrors update_into's closure)
            let margins: Vec<f64> =
                (0..s).map(|i| y[i] * crate::util::dot(x.row(i), &theta)).collect();
            let dirs: Vec<f64> =
                (0..s).map(|i| y[i] * crate::util::dot(x.row(i), &step)).collect();
            let mut acc = 0.0;
            for i in 0..s {
                acc += softplus_neg(margins[i] - t * dirs[i]);
            }
            let analytic = acc / s as f64
                + (crate::util::dot(&theta, &lin) - t * crate::util::dot(&step, &lin))
                + 0.5
                    * (mu0 + rho_dn)
                    * (crate::util::dot(&theta, &theta)
                        - 2.0 * t * crate::util::dot(&theta, &step)
                        + t * t * crate::util::dot(&step, &step));
            // explicit path: form the candidate
            let cand: Vec<f64> = theta.iter().zip(&step).map(|(a, b)| a - t * b).collect();
            let explicit = solver.loss(&cand)
                + crate::util::dot(&cand, &lin)
                + 0.5 * rho_dn * crate::util::dot(&cand, &cand);
            assert!(
                (analytic - explicit).abs() < 1e-9 * (1.0 + explicit.abs()),
                "{analytic} vs {explicit}"
            );
        });
    }

    #[test]
    fn from_shard_shares_data_without_copying() {
        let (x, y) = random_shard(12, 3, 8);
        let sh = Arc::new(Shard { worker: 0, x, y });
        let solver = LogisticSolver::from_shard(Arc::clone(&sh), 0.1, 1.0, 1);
        assert_eq!(Arc::strong_count(&sh), 2);
        assert_eq!(solver.d(), 3);
    }

    #[test]
    fn loss_stable_for_extreme_margins() {
        let (x, y) = random_shard(10, 3, 1);
        let solver = LogisticSolver::new(x, y, 0.1, 1.0, 1);
        let big = vec![1e3; 3];
        let l = solver.loss(&big);
        assert!(l.is_finite() && l > 0.0);
    }

    #[test]
    fn warm_start_converges_same_point() {
        let (x, y) = random_shard(30, 5, 2);
        let mut solver = LogisticSolver::new(x, y, 0.05, 0.5, 2);
        let alpha = vec![0.1; 5];
        let nbr = vec![0.2; 5];
        let cold = solver.update(&alpha, &nbr, &vec![0.0; 5]);
        let warm = solver.update(&alpha, &nbr, &cold);
        for (a, b) in cold.iter().zip(&warm) {
            assert!((a - b).abs() < 1e-7);
        }
    }
}
