//! Centralized reference optimum `f*` (the loss-gap baseline of every
//! figure: the paper plots `|sum_n f_n(theta_n^k) - f*|`).

use crate::config::Task;
use crate::data::Shard;
use crate::linalg::{Cholesky, Mat};
use std::borrow::Borrow;

/// Global linear-regression optimum over all shards:
/// `argmin sum_n 1/2 ||X_n theta - y_n||^2`.
///
/// Generic over [`Borrow<Shard>`] so both owned shard slices (tests) and
/// the engine's shared `Arc<Shard>`s work without copying.
pub fn central_linear_optimum<S: Borrow<Shard>>(shards: &[S]) -> Vec<f64> {
    let d = shards[0].borrow().x.cols();
    let mut gram = Mat::zeros(d, d);
    let mut rhs = vec![0.0; d];
    for sh in shards {
        let sh = sh.borrow();
        gram = gram.add(&sh.x.gram());
        let r = sh.x.t_matvec(&sh.y);
        for i in 0..d {
            rhs[i] += r[i];
        }
    }
    // tiny jitter guards rank-deficient totals (never triggers for the
    // paper's datasets, but keeps the reference robust for tests)
    let chol = Cholesky::new(&gram)
        .or_else(|| Cholesky::new(&gram.clone().add_diag(1e-9)))
        .expect("global Gram not factorizable");
    chol.solve(&rhs)
}

/// Global logistic optimum: Newton on
/// `sum_n [(1/s_n) sum_i log(1+exp(-y x theta)) + (mu0/2)||theta||^2]`
/// (each worker carries its own 1/s_n normalization and ridge, exactly as
/// the decentralized objective sums them).
pub fn central_logistic_optimum<S: Borrow<Shard>>(shards: &[S], mu0: f64) -> Vec<f64> {
    let d = shards[0].borrow().x.cols();
    let n_workers = shards.len() as f64;
    let mut theta = vec![0.0; d];
    for _ in 0..200 {
        let mut grad = vec![0.0; d];
        let mut hess = Mat::zeros(d, d);
        for sh in shards {
            let sh = sh.borrow();
            let inv_s = 1.0 / sh.s() as f64;
            for i in 0..sh.s() {
                let row = sh.x.row(i);
                let z = sh.y[i] * crate::util::dot(row, &theta);
                let p = 1.0 / (1.0 + z.exp());
                let gs = -sh.y[i] * p * inv_s;
                let w = p * (1.0 - p) * inv_s;
                for a in 0..d {
                    grad[a] += gs * row[a];
                    let wa = w * row[a];
                    for b in a..d {
                        hess[(a, b)] += wa * row[b];
                    }
                }
            }
        }
        for a in 0..d {
            for b in 0..a {
                hess[(a, b)] = hess[(b, a)];
            }
            grad[a] += n_workers * mu0 * theta[a];
        }
        let gnorm = crate::util::norm2(&grad);
        if gnorm < 1e-12 * (1.0 + crate::util::norm2(&theta)) {
            break;
        }
        let h = hess.add_diag(n_workers * mu0);
        let step = Cholesky::new(&h).expect("SPD Hessian").solve(&grad);
        for i in 0..d {
            theta[i] -= step[i];
        }
    }
    theta
}

/// Global decentralized objective `sum_n f_n(theta)` at a common point.
pub fn global_objective<S: Borrow<Shard>>(
    shards: &[S],
    task: Task,
    mu0: f64,
    theta: &[f64],
) -> f64 {
    let mut total = 0.0;
    for sh in shards {
        let sh = sh.borrow();
        match task {
            Task::Linear => {
                let pred = sh.x.matvec(theta);
                total += 0.5
                    * pred
                        .iter()
                        .zip(&sh.y)
                        .map(|(p, y)| (p - y) * (p - y))
                        .sum::<f64>();
            }
            Task::Logistic => {
                let inv_s = 1.0 / sh.s() as f64;
                let mut acc = 0.0;
                for i in 0..sh.s() {
                    let z = sh.y[i] * crate::util::dot(sh.x.row(i), theta);
                    acc += if z > 0.0 {
                        (-z).exp().ln_1p()
                    } else {
                        -z + z.exp().ln_1p()
                    };
                }
                total += inv_s * acc + 0.5 * mu0 * crate::util::dot(theta, theta);
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{partition_uniform, synthetic};

    #[test]
    fn linear_optimum_is_stationary() {
        let ds = synthetic::linear_dataset(200, 8, 1);
        let shards = partition_uniform(&ds, 5, 2);
        let theta = central_linear_optimum(&shards);
        // full gradient sum X^T (X theta - y) = 0
        let mut grad = vec![0.0; 8];
        for sh in &shards {
            let resid = sh.x.matvec(&theta);
            let resid: Vec<f64> = resid.iter().zip(&sh.y).map(|(p, y)| p - y).collect();
            let g = sh.x.t_matvec(&resid);
            for i in 0..8 {
                grad[i] += g[i];
            }
        }
        assert!(crate::util::norm2(&grad) < 1e-7);
    }

    #[test]
    fn logistic_optimum_is_stationary() {
        let ds = synthetic::logistic_dataset(240, 6, 2);
        let shards = partition_uniform(&ds, 4, 3);
        let mu0 = 0.05;
        let theta = central_logistic_optimum(&shards, mu0);
        // numeric gradient of the global objective must vanish
        let f0 = global_objective(&shards, Task::Logistic, mu0, &theta);
        let eps = 1e-6;
        for j in 0..6 {
            let mut tp = theta.clone();
            tp[j] += eps;
            let fp = global_objective(&shards, Task::Logistic, mu0, &tp);
            assert!(
                ((fp - f0) / eps).abs() < 1e-4,
                "coord {j}: dir deriv {}",
                (fp - f0) / eps
            );
        }
    }

    #[test]
    fn objective_decreases_at_optimum() {
        let ds = synthetic::linear_dataset(120, 5, 4);
        let shards = partition_uniform(&ds, 3, 1);
        let opt = central_linear_optimum(&shards);
        let f_opt = global_objective(&shards, Task::Linear, 0.0, &opt);
        let f_zero = global_objective(&shards, Task::Linear, 0.0, &vec![0.0; 5]);
        assert!(f_opt < f_zero);
    }
}
