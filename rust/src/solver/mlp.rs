//! One-hidden-layer MLP subproblem solver (two parameter blocks).
//!
//! Model: `yhat_i = sum_r v_r tanh(w_r^T x_i)` with
//! `theta = [vec(W) (hidden x d_in, row-major), v (hidden)]` — the
//! two-block layout reported by [`mlp_blocks`] and threaded through the
//! engines by [`crate::param::Blocks`].
//!
//! Local objective (regression targets):
//!
//! ```text
//! f_n(theta) = (1/(2 s_n)) ||yhat - y||^2 + (mu0/2) ||theta||^2
//! ```
//!
//! The ADMM subproblem adds `<theta, lin>` and `(rho d_n/2)||theta||^2`
//! exactly as for the GLM solvers.  It is nonconvex, so the solver is a
//! *deterministic* block-coordinate descent: the output layer `v` has a
//! closed-form ridge solution given `H = tanh(X W^T)` (solved exactly by
//! the blocked Cholesky), and the hidden layer `W` takes one damped
//! Gauss–Newton step with an Armijo backtrack per outer sweep.  Every
//! operation is a pure function of the inputs, so the three drivers
//! (in-process, coordinator, TCP) stay bit-identical on this model — the
//! same contract the GLM solvers uphold.
//!
//! `theta = 0` is a saddle of this model (`v = 0` kills the Jacobian of
//! the hidden layer), so problems carry the deterministic seeded start
//! produced by [`mlp_theta0`] instead of the all-zeros GLM start.

use super::SubproblemSolver;
use crate::data::Shard;
use crate::linalg::{Cholesky, Mat};
use crate::param::Blocks;
use crate::util::rng::Pcg64;
use std::borrow::Borrow;
use std::sync::Arc;

/// Outer block sweeps per ADMM subproblem solve (warm-started).
const MAX_OUTER_SUB: usize = 40;
/// Gradient-norm stopping tolerance of the subproblem solve.
const TOL_SUB: f64 = 1e-9;
/// Outer sweeps for the centralized reference optimum (cold start).
const MAX_OUTER_CENTRAL: usize = 500;
/// Gradient-norm tolerance of the centralized reference optimum.
const TOL_CENTRAL: f64 = 1e-10;

/// Two-block layout of the MLP parameter vector: `[hidden*d_in, hidden]`.
pub fn mlp_blocks(d_in: usize, hidden: usize) -> Blocks {
    Blocks::from_lens(&[hidden * d_in, hidden])
}

/// Deterministic seeded nonzero start (the zero point is a saddle).
/// Small scaled-normal entries; a pure function of `(d_in, hidden, seed)`
/// so every driver and every resume derives the same start.
pub fn mlp_theta0(d_in: usize, hidden: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::new(seed ^ 0x31A9_77F1);
    let mut theta = vec![0.0; hidden * d_in + hidden];
    let w_scale = 0.5 / (d_in as f64).sqrt();
    for t in theta[..hidden * d_in].iter_mut() {
        *t = w_scale * rng.normal();
    }
    let v_scale = 0.5 / (hidden as f64).sqrt();
    for t in theta[hidden * d_in..].iter_mut() {
        *t = v_scale * rng.normal();
    }
    theta
}

/// Hidden activations `h[(i, r)] = tanh(w_r^T x_i)` and residuals
/// `resid[i] = yhat_i - y_i` at `theta`.
fn forward(sh: &Shard, hidden: usize, theta: &[f64], h: &mut Mat, resid: &mut [f64]) {
    let d_in = sh.x.cols();
    let (w, v) = theta.split_at(hidden * d_in);
    for i in 0..sh.s() {
        let row = sh.x.row(i);
        let mut yhat = 0.0;
        for r in 0..hidden {
            let a = crate::util::dot(&w[r * d_in..(r + 1) * d_in], row).tanh();
            h[(i, r)] = a;
            yhat += v[r] * a;
        }
        resid[i] = yhat - sh.y[i];
    }
}

/// Unscaled data SSE `||yhat - y||^2` at `theta`.
fn data_sse(sh: &Shard, hidden: usize, theta: &[f64]) -> f64 {
    let d_in = sh.x.cols();
    let (w, v) = theta.split_at(hidden * d_in);
    let mut acc = 0.0;
    for i in 0..sh.s() {
        let row = sh.x.row(i);
        let mut yhat = 0.0;
        for r in 0..hidden {
            yhat += v[r] * crate::util::dot(&w[r * d_in..(r + 1) * d_in], row).tanh();
        }
        let e = yhat - sh.y[i];
        acc += e * e;
    }
    acc
}

/// Penalized objective over `shards`:
/// `sum_n (1/(2 s_n))||yhat_n - y_n||^2 + (ridge/2)||theta||^2 + <theta, lin>`.
fn objective(shards: &[&Shard], ridge: f64, lin: &[f64], hidden: usize, theta: &[f64]) -> f64 {
    let mut total = 0.0;
    for sh in shards {
        total += 0.5 / sh.s() as f64 * data_sse(sh, hidden, theta);
    }
    total + 0.5 * ridge * crate::util::dot(theta, theta) + crate::util::dot(theta, lin)
}

/// Full penalized gradient into `out`.
fn gradient(shards: &[&Shard], ridge: f64, lin: &[f64], hidden: usize, theta: &[f64], out: &mut [f64]) {
    let d = theta.len();
    for g in out.iter_mut() {
        *g = 0.0;
    }
    for sh in shards {
        let d_in = sh.x.cols();
        let inv_s = 1.0 / sh.s() as f64;
        let v = &theta[hidden * d_in..];
        let mut h = Mat::zeros(sh.s(), hidden);
        let mut resid = vec![0.0; sh.s()];
        forward(sh, hidden, theta, &mut h, &mut resid);
        for i in 0..sh.s() {
            let row = sh.x.row(i);
            let e = inv_s * resid[i];
            for r in 0..hidden {
                let a = h[(i, r)];
                out[hidden * d_in + r] += e * a;
                let c = e * v[r] * (1.0 - a * a);
                if c != 0.0 {
                    crate::util::axpy(&mut out[r * d_in..(r + 1) * d_in], c, row);
                }
            }
        }
    }
    for j in 0..d {
        out[j] += ridge * theta[j] + lin[j];
    }
}

/// Cholesky with escalating diagonal jitter (the GN/ridge systems are
/// PSD + ridge; jitter only engages for degenerate ridge-free cases).
fn factor_spd(mut a: Mat) -> Cholesky {
    let mut jitter = 1e-12;
    loop {
        if let Some(c) = Cholesky::new(&a) {
            return c;
        }
        a = a.add_diag(jitter);
        jitter *= 100.0;
        assert!(jitter < 1.0, "MLP normal system not factorizable");
    }
}

/// Exact ridge solve of the output layer `v` given the hidden layer:
/// `(sum_n (1/s_n) H_n^T H_n + ridge I) v = sum_n (1/s_n) H_n^T y_n - lin_v`.
fn solve_v(shards: &[&Shard], ridge: f64, lin: &[f64], hidden: usize, theta: &mut [f64]) {
    let d_in = shards[0].x.cols();
    let wlen = hidden * d_in;
    let mut m = Mat::zeros(hidden, hidden);
    let mut rhs = vec![0.0; hidden];
    let mut hrow = vec![0.0; hidden];
    for sh in shards {
        let inv_s = 1.0 / sh.s() as f64;
        let w = &theta[..wlen];
        for i in 0..sh.s() {
            let row = sh.x.row(i);
            for r in 0..hidden {
                hrow[r] = crate::util::dot(&w[r * d_in..(r + 1) * d_in], row).tanh();
            }
            for a in 0..hidden {
                let wa = inv_s * hrow[a];
                rhs[a] += wa * sh.y[i];
                for b in a..hidden {
                    m[(a, b)] += wa * hrow[b];
                }
            }
        }
    }
    for a in 0..hidden {
        for b in 0..a {
            m[(a, b)] = m[(b, a)];
        }
        rhs[a] -= lin[wlen + a];
    }
    let chol = factor_spd(m.add_diag(ridge));
    chol.solve_into(&rhs, &mut theta[wlen..]);
}

/// One damped Gauss–Newton step with Armijo backtrack on the hidden
/// layer `W` (output layer fixed).  `J[i, (r,j)] = v_r (1 - h_ir^2) x_ij`.
fn gn_step_w(shards: &[&Shard], ridge: f64, lin: &[f64], hidden: usize, theta: &mut [f64]) {
    let d_in = shards[0].x.cols();
    let wlen = hidden * d_in;
    let mut a = Mat::zeros(wlen, wlen);
    let mut g = vec![0.0; wlen];
    let mut jrow = vec![0.0; wlen];
    for sh in shards {
        let inv_s = 1.0 / sh.s() as f64;
        let v = &theta[wlen..];
        let mut h = Mat::zeros(sh.s(), hidden);
        let mut resid = vec![0.0; sh.s()];
        forward(sh, hidden, theta, &mut h, &mut resid);
        for i in 0..sh.s() {
            let row = sh.x.row(i);
            for r in 0..hidden {
                let act = h[(i, r)];
                let c = v[r] * (1.0 - act * act);
                for j in 0..d_in {
                    jrow[r * d_in + j] = c * row[j];
                }
            }
            let e = inv_s * resid[i];
            for p in 0..wlen {
                let jp = jrow[p];
                g[p] += e * jp;
                if jp == 0.0 {
                    continue;
                }
                let wjp = inv_s * jp;
                let arow = a.row_mut(p);
                for q in p..wlen {
                    arow[q] += wjp * jrow[q];
                }
            }
        }
    }
    for p in 0..wlen {
        for q in 0..p {
            a[(p, q)] = a[(q, p)];
        }
        g[p] += ridge * theta[p] + lin[p];
    }
    let chol = factor_spd(a.add_diag(ridge));
    let step = chol.solve(&g);
    let slope = crate::util::dot(&g, &step);
    let f0 = objective(shards, ridge, lin, hidden, theta);
    // trial candidates are written from the saved start (not undone with
    // `+=`, which would not restore the start bit-exactly)
    let w0: Vec<f64> = theta[..wlen].to_vec();
    let mut t = 1.0;
    loop {
        for p in 0..wlen {
            theta[p] = w0[p] - t * step[p];
        }
        let ft = objective(shards, ridge, lin, hidden, theta);
        if ft <= f0 - 1e-4 * t * slope || t < 1e-8 {
            break;
        }
        t *= 0.5;
    }
}

/// Deterministic block-coordinate descent: exact `v` ridge + one GN step
/// on `W` per sweep, stopping on the full penalized gradient norm.
fn block_descent(
    shards: &[&Shard],
    ridge: f64,
    lin: &[f64],
    hidden: usize,
    theta: &mut [f64],
    max_outer: usize,
    tol: f64,
) {
    let mut g = vec![0.0; theta.len()];
    for _ in 0..max_outer {
        solve_v(shards, ridge, lin, hidden, theta);
        gn_step_w(shards, ridge, lin, hidden, theta);
        gradient(shards, ridge, lin, hidden, theta, &mut g);
        if crate::util::norm2(&g) < tol * (1.0 + crate::util::norm2(theta)) {
            break;
        }
    }
    // final exact v-solve so the output layer is consistent with the
    // accepted hidden layer (pure, deterministic)
    solve_v(shards, ridge, lin, hidden, theta);
}

/// Centralized reference optimum of `sum_n f_n(theta)` (block descent
/// from the seeded start; each worker carries its own `1/s_n`
/// normalization and ridge, exactly as the decentralized objective sums
/// them — mirrors [`super::central::central_logistic_optimum`]).
pub fn central_mlp_optimum<S: Borrow<Shard>>(
    shards: &[S],
    mu0: f64,
    hidden: usize,
    theta0: &[f64],
) -> Vec<f64> {
    let parts: Vec<&Shard> = shards.iter().map(Borrow::borrow).collect();
    let ridge = shards.len() as f64 * mu0;
    let lin = vec![0.0; theta0.len()];
    let mut theta = theta0.to_vec();
    block_descent(&parts, ridge, &lin, hidden, &mut theta, MAX_OUTER_CENTRAL, TOL_CENTRAL);
    theta
}

/// Global decentralized MLP objective `sum_n f_n(theta)` at a common
/// point (per-shard `1/(2 s_n)` SSE + per-shard ridge, matching
/// [`super::central::global_objective`]'s conventions).
pub fn mlp_global_objective<S: Borrow<Shard>>(
    shards: &[S],
    mu0: f64,
    hidden: usize,
    theta: &[f64],
) -> f64 {
    let quad = crate::util::dot(theta, theta);
    let mut total = 0.0;
    for sh in shards {
        let sh = sh.borrow();
        total += 0.5 / sh.s() as f64 * data_sse(sh, hidden, theta) + 0.5 * mu0 * quad;
    }
    total
}

/// Gauss–Newton block-coordinate solver for one worker's MLP shard.
pub struct MlpSolver {
    /// Shared shard; never copied per worker.
    data: Arc<Shard>,
    mu0: f64,
    rho: f64,
    rho_dn: f64,
    hidden: usize,
    /// persistent scratch: linear term `alpha - rho * nbr_sum`
    lin: Vec<f64>,
}

impl MlpSolver {
    /// Build from a shared shard.
    pub fn from_shard(
        data: Arc<Shard>,
        mu0: f64,
        rho: f64,
        degree: usize,
        hidden: usize,
    ) -> MlpSolver {
        assert_eq!(data.x.rows(), data.y.len());
        assert!(!data.y.is_empty());
        assert!(hidden >= 1);
        let d = hidden * data.x.cols() + hidden;
        MlpSolver {
            data,
            mu0,
            rho,
            rho_dn: rho * degree as f64,
            hidden,
            lin: vec![0.0; d],
        }
    }

    /// Owned-data convenience constructor (tests/benches).
    pub fn new(x: Mat, y: Vec<f64>, mu0: f64, rho: f64, degree: usize, hidden: usize) -> MlpSolver {
        Self::from_shard(Arc::new(Shard { worker: 0, x, y }), mu0, rho, degree, hidden)
    }
}

impl SubproblemSolver for MlpSolver {
    fn update_into(&mut self, alpha: &[f64], nbr_sum: &[f64], theta: &mut [f64]) {
        let d = self.lin.len();
        assert_eq!(alpha.len(), d);
        assert_eq!(nbr_sum.len(), d);
        assert_eq!(theta.len(), d);
        for i in 0..d {
            self.lin[i] = alpha[i] - self.rho * nbr_sum[i];
        }
        let shards = [&*self.data];
        block_descent(
            &shards,
            self.mu0 + self.rho_dn,
            &self.lin,
            self.hidden,
            theta,
            MAX_OUTER_SUB,
            TOL_SUB,
        );
    }

    fn loss(&self, theta: &[f64]) -> f64 {
        let inv_s = 1.0 / self.data.s() as f64;
        0.5 * inv_s * data_sse(&self.data, self.hidden, theta)
            + 0.5 * self.mu0 * crate::util::dot(theta, theta)
    }

    fn d(&self) -> usize {
        self.lin.len()
    }

    fn blocks(&self) -> Blocks {
        mlp_blocks(self.data.x.cols(), self.hidden)
    }

    fn grad_into(&self, theta: &[f64], out: &mut [f64]) {
        let zeros = vec![0.0; theta.len()];
        gradient(&[&*self.data], self.mu0, &zeros, self.hidden, theta, out);
    }

    fn set_degree(&mut self, degree: usize) {
        assert!(degree >= 1, "degree-0 workers are never solved");
        // rho_dn is the only degree-dependent term, so mutating it is
        // bit-identical to constructing at `degree`
        self.rho_dn = self.rho * degree as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    fn random_shard(s: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::zeros(s, d);
        for i in 0..s {
            for j in 0..d {
                x[(i, j)] = rng.normal();
            }
        }
        let y = rng.normal_vec(s);
        (x, y)
    }

    #[test]
    fn blocks_layout() {
        let b = mlp_blocks(4, 3);
        assert_eq!(b.count(), 2);
        assert_eq!(b.len_of(0), 12);
        assert_eq!(b.len_of(1), 3);
        assert_eq!(b.d(), 15);
    }

    #[test]
    fn theta0_deterministic_nonzero_seeded() {
        let a = mlp_theta0(4, 3, 7);
        let b = mlp_theta0(4, 3, 7);
        let c = mlp_theta0(4, 3, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 15);
        assert!(a.iter().all(|t| *t != 0.0 && t.abs() < 5.0));
    }

    #[test]
    fn gradient_matches_numeric() {
        check("MLP analytic gradient == numeric", 20, |g| {
            let d_in = g.usize_in(1, 4);
            let hidden = g.usize_in(1, 3);
            let s = g.usize_in(3, 12);
            let (x, y) = random_shard(s, d_in, g.u64());
            let sh = Shard { worker: 0, x, y };
            let ridge = g.f64_in(0.01, 0.5);
            let d = hidden * d_in + hidden;
            let theta = g.normal_vec(d);
            let lin = g.normal_vec(d);
            let mut grad = vec![0.0; d];
            gradient(&[&sh], ridge, &lin, hidden, &theta, &mut grad);
            let f0 = objective(&[&sh], ridge, &lin, hidden, &theta);
            let eps = 1e-6;
            for j in 0..d {
                let mut tp = theta.clone();
                tp[j] += eps;
                let fp = objective(&[&sh], ridge, &lin, hidden, &tp);
                let num = (fp - f0) / eps;
                assert!(
                    (num - grad[j]).abs() < 1e-4 * (1.0 + num.abs()),
                    "coord {j}: numeric {num} vs analytic {}",
                    grad[j]
                );
            }
        });
    }

    #[test]
    fn update_reaches_stationarity() {
        check("MLP subproblem update is near-stationary", 10, |g| {
            let d_in = g.usize_in(1, 3);
            let hidden = g.usize_in(1, 3);
            let s = g.usize_in(6, 20);
            let (x, y) = random_shard(s, d_in, g.u64());
            let mu0 = g.f64_in(0.01, 0.3);
            let rho = g.f64_in(0.2, 1.5);
            let degree = g.usize_in(1, 3);
            let d = hidden * d_in + hidden;
            let mut solver = MlpSolver::new(x.clone(), y.clone(), mu0, rho, degree, hidden);
            let alpha = g.normal_vec(d);
            let nbr = g.normal_vec(d);
            let mut theta = mlp_theta0(d_in, hidden, g.u64());
            solver.update_into(&alpha, &nbr, &mut theta);
            // penalized gradient: grad f_n + (alpha - rho nbr) + rho d theta
            let sh = Shard { worker: 0, x, y };
            let lin: Vec<f64> = (0..d).map(|i| alpha[i] - rho * nbr[i]).collect();
            let mut grad = vec![0.0; d];
            gradient(&[&sh], mu0 + rho * degree as f64, &lin, hidden, &theta, &mut grad);
            let gn = crate::util::norm2(&grad);
            assert!(gn < 1e-5 * (1.0 + crate::util::norm2(&theta)), "gnorm={gn}");
        });
    }

    #[test]
    fn update_is_deterministic_and_pure() {
        let (x, y) = random_shard(15, 3, 11);
        let hidden = 2;
        let d = hidden * 3 + hidden;
        let alpha = vec![0.05; d];
        let nbr = vec![0.1; d];
        let warm = mlp_theta0(3, hidden, 4);
        let mut s1 = MlpSolver::new(x.clone(), y.clone(), 0.05, 0.8, 2, hidden);
        let mut s2 = MlpSolver::new(x, y, 0.05, 0.8, 2, hidden);
        let mut t1 = warm.clone();
        let mut t2 = warm;
        s1.update_into(&alpha, &nbr, &mut t1);
        s2.update_into(&alpha, &nbr, &mut t2);
        assert_eq!(t1, t2);
        // repeated solve from the minimizer stays put (fixed point)
        let before = t1.clone();
        s1.update_into(&alpha, &nbr, &mut t1);
        for (a, b) in before.iter().zip(&t1) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn set_degree_matches_from_scratch_bit_for_bit() {
        let (x, y) = random_shard(12, 2, 5);
        let hidden = 2;
        let d = hidden * 2 + hidden;
        let mut mutated = MlpSolver::new(x.clone(), y.clone(), 0.1, 0.7, 1, hidden);
        mutated.set_degree(3);
        let mut fresh = MlpSolver::new(x, y, 0.1, 0.7, 3, hidden);
        let alpha = vec![0.2; d];
        let nbr = vec![-0.1; d];
        let warm = mlp_theta0(2, hidden, 9);
        let a = mutated.update(&alpha, &nbr, &warm);
        let b = fresh.update(&alpha, &nbr, &warm);
        assert_eq!(a, b, "churn re-derivation must be bit-identical");
    }

    #[test]
    fn central_optimum_improves_on_start() {
        let (x, y) = random_shard(40, 3, 3);
        let ds_shards = vec![
            Shard { worker: 0, x: x.clone(), y: y.clone() },
            Shard { worker: 1, x, y },
        ];
        let hidden = 3;
        let mu0 = 0.01;
        let theta0 = mlp_theta0(3, hidden, 13);
        let star = central_mlp_optimum(&ds_shards, mu0, hidden, &theta0);
        let f0 = mlp_global_objective(&ds_shards, mu0, hidden, &theta0);
        let fs = mlp_global_objective(&ds_shards, mu0, hidden, &star);
        assert!(fs < f0, "optimizer must improve: {fs} vs {f0}");
        // near-stationary: numeric directional derivatives vanish
        let parts: Vec<&Shard> = ds_shards.iter().collect();
        let lin = vec![0.0; theta0.len()];
        let mut grad = vec![0.0; theta0.len()];
        gradient(&parts, 2.0 * mu0, &lin, hidden, &star, &mut grad);
        let gn = crate::util::norm2(&grad);
        assert!(gn < 1e-5 * (1.0 + crate::util::norm2(&star)), "gnorm={gn}");
    }

    #[test]
    fn loss_and_global_objective_agree_on_one_shard() {
        let (x, y) = random_shard(10, 2, 6);
        let hidden = 2;
        let solver = MlpSolver::new(x.clone(), y.clone(), 0.05, 1.0, 1, hidden);
        let theta = mlp_theta0(2, hidden, 2);
        let sh = Shard { worker: 0, x, y };
        let via_global = mlp_global_objective(std::slice::from_ref(&sh), 0.05, hidden, &theta);
        assert!((solver.loss(&theta) - via_global).abs() < 1e-12);
    }

    #[test]
    fn grad_into_matches_gradient_helper() {
        let (x, y) = random_shard(8, 3, 12);
        let hidden = 2;
        let d = hidden * 3 + hidden;
        let solver = MlpSolver::new(x.clone(), y.clone(), 0.2, 1.0, 2, hidden);
        let theta = mlp_theta0(3, hidden, 1);
        let mut out = vec![0.0; d];
        solver.grad_into(&theta, &mut out);
        let sh = Shard { worker: 0, x, y };
        let zeros = vec![0.0; d];
        let mut want = vec![0.0; d];
        gradient(&[&sh], 0.2, &zeros, hidden, &theta, &mut want);
        assert_eq!(out, want);
    }
}
