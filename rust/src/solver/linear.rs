//! Native closed-form solver for the linear-regression subproblem.
//!
//! With `f_n(theta) = 1/2 ||X theta - y||^2`, the subproblem minimizer is
//! the solution of `(X^T X + rho d_n I) theta = X^T y - alpha + rho * nbr`.
//! The SPD matrix is factored **once** at construction (it never changes
//! over a run), so the per-iteration hot path is one O(d^2) solve — the
//! same split the AOT artifacts use (`linear_setup` once, `linear_update`
//! per iteration with the precomputed inverse).

use super::SubproblemSolver;
use crate::linalg::{Cholesky, Mat};

/// Cached-factorization linear subproblem solver.
pub struct LinearSolver {
    xtx: Mat,
    xty: Vec<f64>,
    chol: Cholesky,
    rho: f64,
    x: Mat,
    y: Vec<f64>,
}

impl LinearSolver {
    /// Build from the worker's shard; factors `X^T X + rho * degree * I`.
    pub fn new(x: Mat, y: Vec<f64>, rho: f64, degree: usize) -> LinearSolver {
        assert_eq!(x.rows(), y.len());
        let xtx = x.gram();
        let xty = x.t_matvec(&y);
        let a = xtx.clone().add_diag(rho * degree as f64);
        let chol = Cholesky::new(&a)
            .expect("X^T X + rho d I must be SPD (rho > 0, degree >= 1)");
        LinearSolver { xtx, xty, chol, rho, x, y }
    }

    /// The Gram system (used to feed the PJRT differential tests).
    pub fn gram_system(&self) -> (&Mat, &[f64]) {
        (&self.xtx, &self.xty)
    }

    /// Explicit inverse of the update matrix (input of the AOT
    /// `linear_update` artifact).
    pub fn a_inverse(&self) -> Mat {
        self.chol.inverse()
    }
}

impl SubproblemSolver for LinearSolver {
    fn update(&mut self, alpha: &[f64], nbr_sum: &[f64], _warm: &[f64]) -> Vec<f64> {
        let d = self.xty.len();
        assert_eq!(alpha.len(), d);
        assert_eq!(nbr_sum.len(), d);
        let mut rhs = vec![0.0; d];
        for i in 0..d {
            rhs[i] = self.xty[i] - alpha[i] + self.rho * nbr_sum[i];
        }
        self.chol.solve(&rhs)
    }

    fn loss(&self, theta: &[f64]) -> f64 {
        let pred = self.x.matvec(theta);
        0.5 * pred
            .iter()
            .zip(&self.y)
            .map(|(p, y)| (p - y) * (p - y))
            .sum::<f64>()
    }

    fn d(&self) -> usize {
        self.xty.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;
    use crate::util::rng::Pcg64;

    fn random_shard(s: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::zeros(s, d);
        for i in 0..s {
            for j in 0..d {
                x[(i, j)] = rng.normal();
            }
        }
        let y = rng.normal_vec(s);
        (x, y)
    }

    #[test]
    fn stationarity_of_update() {
        check("linear update satisfies the KKT condition", 50, |g| {
            let d = g.usize_in(1, 20);
            let s = g.usize_in(d, 60);
            let (x, y) = random_shard(s, d, g.u64());
            let rho = g.f64_in(0.1, 3.0);
            let degree = g.usize_in(1, 5);
            let mut solver = LinearSolver::new(x.clone(), y.clone(), rho, degree);
            let alpha = g.normal_vec(d);
            let nbr = g.normal_vec(d);
            let theta = solver.update(&alpha, &nbr, &vec![0.0; d]);
            // gradient: X^T(X theta - y) + alpha - rho*nbr + rho*degree*theta = 0
            let resid = x.matvec(&theta);
            let resid: Vec<f64> = resid.iter().zip(&y).map(|(p, y)| p - y).collect();
            let mut grad = x.t_matvec(&resid);
            for i in 0..d {
                grad[i] += alpha[i] - rho * nbr[i] + rho * degree as f64 * theta[i];
            }
            let gnorm = crate::util::norm2(&grad);
            assert!(gnorm < 1e-7 * (1.0 + crate::util::norm2(&theta)), "gnorm={gnorm}");
        });
    }

    #[test]
    fn loss_is_half_sse() {
        let (x, y) = random_shard(10, 3, 1);
        let solver = LinearSolver::new(x.clone(), y.clone(), 1.0, 1);
        let theta = vec![0.0; 3];
        let want: f64 = 0.5 * y.iter().map(|v| v * v).sum::<f64>();
        assert!((solver.loss(&theta) - want).abs() < 1e-10);
    }

    #[test]
    fn a_inverse_matches_solve() {
        let (x, y) = random_shard(20, 6, 2);
        let solver = LinearSolver::new(x, y, 0.7, 2);
        let inv = solver.a_inverse();
        let rhs: Vec<f64> = (0..6).map(|i| i as f64 - 2.0).collect();
        let via_inv = inv.matvec(&rhs);
        let via_chol = solver.chol.solve(&rhs);
        for (a, b) in via_inv.iter().zip(&via_chol) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn underdetermined_shard_still_spd() {
        // s < d: X^T X singular, but + rho d I keeps it SPD
        let (x, y) = random_shard(3, 10, 3);
        let mut solver = LinearSolver::new(x, y, 0.5, 1);
        let theta = solver.update(&vec![0.0; 10], &vec![0.0; 10], &vec![0.0; 10]);
        assert!(theta.iter().all(|t| t.is_finite()));
    }
}
