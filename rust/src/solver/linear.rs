//! Native closed-form solver for the linear-regression subproblem.
//!
//! With `f_n(theta) = 1/2 ||X theta - y||^2`, the subproblem minimizer is
//! the solution of `(X^T X + rho d_n I) theta = X^T y - alpha + rho * nbr`.
//! The SPD matrix is factored **once** at construction (it never changes
//! over a run), so the per-iteration hot path is one O(d^2) solve — the
//! same split the AOT artifacts use (`linear_setup` once, `linear_update`
//! per iteration with the precomputed inverse).
//!
//! Perf: construction borrows the worker's shard through a shared
//! [`Arc<Shard>`] (no per-worker copy of `X`/`y`), and `update_into`
//! reuses a persistent right-hand-side buffer + the caller's `theta`
//! buffer, so a run allocates nothing per iteration.  The one-time setup
//! runs on the blocked kernels: `X^T X` through the SYRK Gram kernel,
//! the factorization through the right-looking blocked Cholesky, and
//! [`LinearSolver::a_inverse`] through the one-sweep blocked multi-RHS
//! solve (the seed solved one identity column at a time).  All of these
//! inherit the process-wide kernel tier
//! ([`crate::linalg::KernelTier`]): on x86-64 with AVX2+FMA the Gram
//! and factorization run vectorized (and pool across threads at large
//! `d`), while the per-iteration solve is tier-stable — its backward
//! sweep is axpy-built and bit-identical across tiers.

use super::SubproblemSolver;
use crate::data::Shard;
use crate::linalg::{Cholesky, Mat};
use std::sync::Arc;

/// Cached-factorization linear subproblem solver.
pub struct LinearSolver {
    xtx: Mat,
    xty: Vec<f64>,
    chol: Cholesky,
    rho: f64,
    /// Shared shard (loss evaluation); never copied per worker.
    data: Arc<Shard>,
    /// Persistent per-iteration right-hand-side scratch.
    rhs: Vec<f64>,
}

impl LinearSolver {
    /// Build from a shared shard; factors `X^T X + rho * degree * I`.
    pub fn from_shard(data: Arc<Shard>, rho: f64, degree: usize) -> LinearSolver {
        assert_eq!(data.x.rows(), data.y.len());
        let xtx = data.x.gram();
        let xty = data.x.t_matvec(&data.y);
        let a = xtx.clone().add_diag(rho * degree as f64);
        let chol = Cholesky::new(&a)
            .expect("X^T X + rho d I must be SPD (rho > 0, degree >= 1)");
        let d = xty.len();
        LinearSolver { xtx, xty, chol, rho, data, rhs: vec![0.0; d] }
    }

    /// Owned-data convenience constructor (tests/benches).
    pub fn new(x: Mat, y: Vec<f64>, rho: f64, degree: usize) -> LinearSolver {
        Self::from_shard(Arc::new(Shard { worker: 0, x, y }), rho, degree)
    }

    /// The Gram system (used to feed the PJRT differential tests).
    pub fn gram_system(&self) -> (&Mat, &[f64]) {
        (&self.xtx, &self.xty)
    }

    /// Explicit inverse of the update matrix (input of the AOT
    /// `linear_update` artifact).
    pub fn a_inverse(&self) -> Mat {
        self.chol.inverse()
    }
}

impl SubproblemSolver for LinearSolver {
    fn update_into(&mut self, alpha: &[f64], nbr_sum: &[f64], theta: &mut [f64]) {
        let d = self.xty.len();
        assert_eq!(alpha.len(), d);
        assert_eq!(nbr_sum.len(), d);
        assert_eq!(theta.len(), d);
        for i in 0..d {
            self.rhs[i] = self.xty[i] - alpha[i] + self.rho * nbr_sum[i];
        }
        self.chol.solve_into(&self.rhs, theta);
    }

    fn loss(&self, theta: &[f64]) -> f64 {
        // row-streamed residual: no prediction vector is materialized,
        // so trace recording stays allocation-free on this solver
        let mut acc = 0.0;
        for (i, y) in self.data.y.iter().enumerate() {
            let r = crate::util::dot(self.data.x.row(i), theta) - y;
            acc += r * r;
        }
        0.5 * acc
    }

    fn d(&self) -> usize {
        self.xty.len()
    }

    fn grad_into(&self, theta: &[f64], out: &mut [f64]) {
        // grad f_n = X^T (X theta - y), row-streamed like `loss`
        let d = self.xty.len();
        assert_eq!(theta.len(), d);
        assert_eq!(out.len(), d);
        for g in out.iter_mut() {
            *g = 0.0;
        }
        for (i, y) in self.data.y.iter().enumerate() {
            let row = self.data.x.row(i);
            let r = crate::util::dot(row, theta) - y;
            for j in 0..d {
                out[j] += r * row[j];
            }
        }
    }

    fn set_degree(&mut self, degree: usize) {
        assert!(degree >= 1, "degree-0 workers are never solved");
        // re-factor from the retained Gram matrix: a pure function of
        // (xtx, rho, degree), so a solver mutated to `degree` is
        // bit-identical to one constructed at `degree`
        let a = self.xtx.clone().add_diag(self.rho * degree as f64);
        self.chol = Cholesky::new(&a)
            .expect("X^T X + rho d I must be SPD (rho > 0, degree >= 1)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;
    use crate::util::rng::Pcg64;

    fn random_shard(s: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::zeros(s, d);
        for i in 0..s {
            for j in 0..d {
                x[(i, j)] = rng.normal();
            }
        }
        let y = rng.normal_vec(s);
        (x, y)
    }

    #[test]
    fn stationarity_of_update() {
        check("linear update satisfies the KKT condition", 50, |g| {
            let d = g.usize_in(1, 20);
            let s = g.usize_in(d, 60);
            let (x, y) = random_shard(s, d, g.u64());
            let rho = g.f64_in(0.1, 3.0);
            let degree = g.usize_in(1, 5);
            let mut solver = LinearSolver::new(x.clone(), y.clone(), rho, degree);
            let alpha = g.normal_vec(d);
            let nbr = g.normal_vec(d);
            let theta = solver.update(&alpha, &nbr, &vec![0.0; d]);
            // gradient: X^T(X theta - y) + alpha - rho*nbr + rho*degree*theta = 0
            let resid = x.matvec(&theta);
            let resid: Vec<f64> = resid.iter().zip(&y).map(|(p, y)| p - y).collect();
            let mut grad = x.t_matvec(&resid);
            for i in 0..d {
                grad[i] += alpha[i] - rho * nbr[i] + rho * degree as f64 * theta[i];
            }
            let gnorm = crate::util::norm2(&grad);
            assert!(gnorm < 1e-7 * (1.0 + crate::util::norm2(&theta)), "gnorm={gnorm}");
        });
    }

    #[test]
    fn update_into_matches_update_and_ignores_stale_theta() {
        let (x, y) = random_shard(12, 4, 7);
        let mut solver = LinearSolver::new(x, y, 1.3, 2);
        let alpha = vec![0.2, -0.4, 0.0, 1.0];
        let nbr = vec![1.0, 0.5, -0.5, 0.25];
        let via_update = solver.update(&alpha, &nbr, &vec![0.0; 4]);
        let mut theta = vec![9.0; 4]; // closed form: warm start is irrelevant
        solver.update_into(&alpha, &nbr, &mut theta);
        assert_eq!(via_update, theta);
    }

    #[test]
    fn from_shard_shares_data_without_copying() {
        let (x, y) = random_shard(10, 3, 9);
        let sh = Arc::new(Shard { worker: 0, x, y });
        let solver = LinearSolver::from_shard(Arc::clone(&sh), 1.0, 1);
        // two strong refs: the Arc here and the solver's — no data clone
        assert_eq!(Arc::strong_count(&sh), 2);
        assert_eq!(solver.d(), 3);
    }

    #[test]
    fn loss_is_half_sse() {
        let (x, y) = random_shard(10, 3, 1);
        let solver = LinearSolver::new(x.clone(), y.clone(), 1.0, 1);
        let theta = vec![0.0; 3];
        let want: f64 = 0.5 * y.iter().map(|v| v * v).sum::<f64>();
        assert!((solver.loss(&theta) - want).abs() < 1e-10);
    }

    #[test]
    fn a_inverse_matches_solve() {
        let (x, y) = random_shard(20, 6, 2);
        let solver = LinearSolver::new(x, y, 0.7, 2);
        let inv = solver.a_inverse();
        let rhs: Vec<f64> = (0..6).map(|i| i as f64 - 2.0).collect();
        let via_inv = inv.matvec(&rhs);
        let via_chol = solver.chol.solve(&rhs);
        for (a, b) in via_inv.iter().zip(&via_chol) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn set_degree_matches_from_scratch_bit_for_bit() {
        check("set_degree == fresh construction", 30, |g| {
            let d = g.usize_in(1, 12);
            let s = g.usize_in(d, 40);
            let (x, y) = random_shard(s, d, g.u64());
            let rho = g.f64_in(0.1, 3.0);
            let (d_old, d_new) = (g.usize_in(1, 6), g.usize_in(1, 6));
            let mut mutated = LinearSolver::new(x.clone(), y.clone(), rho, d_old);
            mutated.set_degree(d_new);
            let mut fresh = LinearSolver::new(x, y, rho, d_new);
            let alpha = g.normal_vec(d);
            let nbr = g.normal_vec(d);
            let a = mutated.update(&alpha, &nbr, &vec![0.0; d]);
            let b = fresh.update(&alpha, &nbr, &vec![0.0; d]);
            assert_eq!(a, b, "churn re-derivation must be bit-identical");
        });
    }

    #[test]
    fn underdetermined_shard_still_spd() {
        // s < d: X^T X singular, but + rho d I keeps it SPD
        let (x, y) = random_shard(3, 10, 3);
        let mut solver = LinearSolver::new(x, y, 0.5, 1);
        let theta = solver.update(&vec![0.0; 10], &vec![0.0; 10], &vec![0.0; 10]);
        assert!(theta.iter().all(|t| t.is_finite()));
    }
}
