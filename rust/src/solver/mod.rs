//! Per-worker subproblem solvers.
//!
//! Every GGADMM-family iteration solves, at worker `n` (paper eqs. (21)/(22)):
//!
//! ```text
//! theta_n^{k+1} = argmin_theta f_n(theta)
//!                 + <theta, alpha_n - rho * sum_{m in N_n} theta_hat_m>
//!                 + (rho d_n / 2) ||theta||^2
//! ```
//!
//! [`SubproblemSolver`] abstracts over the two execution backends:
//! * the **native** Rust solvers in [`linear`] / [`logistic`] (closed-form
//!   ridge with a cached Cholesky factor; damped Newton), and
//! * the **PJRT** solvers in [`crate::runtime`] that execute the AOT HLO
//!   artifacts produced by the JAX/Pallas layers.
//!
//! Both are differential-tested against each other; experiments can select
//! either via [`Backend`].

pub mod central;
pub mod linear;
pub mod logistic;
pub mod mlp;

pub use central::{central_linear_optimum, central_logistic_optimum, global_objective};
pub use linear::LinearSolver;
pub use logistic::LogisticSolver;
pub use mlp::MlpSolver;

/// Execution backend for the per-iteration subproblem solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust solvers (always available).
    Native,
    /// AOT HLO artifacts executed through the PJRT CPU client.
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend, String> {
        match s {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            _ => Err(format!("unknown backend '{s}' (expected native|pjrt)")),
        }
    }
}

/// A worker's local subproblem solver.  `rho` and the worker degree are
/// baked in at construction; under a static graph they are constant over
/// a run, and churn (worker join/leave) re-derives the degree-dependent
/// terms through [`SubproblemSolver::set_degree`].
pub trait SubproblemSolver: Send {
    /// Solve the penalized subproblem in place given the worker's dual
    /// `alpha` and the sum of its neighbors' latest (reconstructed)
    /// models.  `theta` enters holding the warm start and exits holding
    /// the minimizer — the per-iteration hot path allocates nothing.
    fn update_into(&mut self, alpha: &[f64], nbr_sum: &[f64], theta: &mut [f64]);

    /// Allocating convenience wrapper around [`Self::update_into`]
    /// (tests, benches and diagnostics; off the hot path).
    fn update(&mut self, alpha: &[f64], nbr_sum: &[f64], warm: &[f64]) -> Vec<f64> {
        let mut theta = warm.to_vec();
        self.update_into(alpha, nbr_sum, &mut theta);
        theta
    }

    /// Local objective `f_n(theta)` (no penalty terms).
    fn loss(&self, theta: &[f64]) -> f64;

    /// Model dimension.
    fn d(&self) -> usize;

    /// Parameter-block layout of this solver's model.  Single-block for
    /// the GLM solvers; the MLP reports `[vec(W), v]`.  The default is
    /// the degenerate flat layout, so existing solvers are untouched.
    fn blocks(&self) -> crate::param::Blocks {
        crate::param::Blocks::single(self.d())
    }

    /// Gradient of the *local* objective `f_n` at `theta` (no penalty
    /// terms), written into `out` — the first-order oracle of the QDGD
    /// baseline.  Solvers that only serve ADMM variants may leave the
    /// default, which panics.
    fn grad_into(&self, theta: &[f64], out: &mut [f64]) {
        let _ = (theta, out);
        panic!("this solver has no first-order oracle (required by qdgd)");
    }

    /// Re-derive the degree-dependent penalty terms after a neighbor
    /// change (churn).  `degree` is the *solver* degree — twice the graph
    /// degree for Jacobian-anchored schedules, matching what the engine
    /// passed at construction.  Must be a pure function of `degree`: the
    /// result is bit-identical whether the solver was built at this
    /// degree or mutated into it, which the checkpoint/resume and engine
    /// equivalence tests rely on.  `degree >= 1` (degree-0 workers are
    /// skipped by the engines, never solved).
    fn set_degree(&mut self, degree: usize);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("native").unwrap(), Backend::Native);
        assert_eq!(Backend::parse("pjrt").unwrap(), Backend::Pjrt);
        assert!(Backend::parse("gpu").is_err());
    }
}
