//! PJRT execution backend (feature `pjrt`): load the AOT HLO-text
//! artifacts and execute them on the per-iteration hot path.
//!
//! One [`PjrtContext`] per run wraps the `xla` crate's CPU PJRT client and
//! an executable cache keyed by artifact name; [`pjrt_solver`] builds a
//! [`SubproblemSolver`] whose `update_into` dispatches to the compiled
//! `linear_update_{d}` / `logistic_newton_{s}x{d}` artifacts (the HLO that
//! the JAX Layer-2 model — calling the Pallas Layer-1 kernels — lowered
//! to).  HLO **text** is the interchange format; see `python/compile/aot.py`.
//!
//! The whole module is compiled only with `--features pjrt`, which
//! requires a vendored `xla` crate (see rust/Cargo.toml); the default
//! build ships the stub in [`super`] instead.

use super::manifest::Manifest;
use crate::config::Task;
use crate::data::Shard;
use crate::linalg::{Cholesky, Mat};
use crate::solver::SubproblemSolver;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

/// Shared PJRT client + executable cache for one run.
pub struct PjrtContext {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: RefCell<BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtContext {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<PjrtContext, String> {
        let client = xla::PjRtClient::cpu().map_err(|e| format!("PJRT client: {e}"))?;
        let manifest = Manifest::load(dir)?;
        Ok(PjrtContext { client, manifest, executables: RefCell::new(BTreeMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>, String> {
        if let Some(e) = self.executables.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .by_name(name)
            .ok_or_else(|| format!("artifact '{name}' not in manifest"))?;
        let path = spec
            .file
            .to_str()
            .ok_or_else(|| "non-utf8 artifact path".to_string())?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| format!("parse {path}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("compile {name}: {e}"))?;
        let exe = Rc::new(exe);
        self.executables
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on f32 inputs; returns the flattened f32
    /// outputs of the (tupled) result.
    pub fn execute(
        &self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<Vec<f32>>, String> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| format!("execute {name}: {e}"))?;
        Self::read_outputs(name, result)
    }

    /// Hot-path variant: execute on pre-staged device buffers (constants
    /// are uploaded once at solver construction; only the small changing
    /// vectors are transferred per call).
    pub fn execute_buffers(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Vec<f32>>, String> {
        let result = exe
            .execute_b(inputs)
            .map_err(|e| format!("execute {name}: {e}"))?;
        Self::read_outputs(name, result)
    }

    /// Upload an f32 tensor to the device.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer, String> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| format!("upload: {e}"))
    }

    fn read_outputs(
        name: &str,
        result: Vec<Vec<xla::PjRtBuffer>>,
    ) -> Result<Vec<Vec<f32>>, String> {
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch {name}: {e}"))?;
        let parts = lit.to_tuple().map_err(|e| format!("untuple {name}: {e}"))?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| format!("read {name}: {e}")))
            .collect()
    }
}

/// f32 literal helpers.
fn lit_vec(v: &[f64]) -> xla::Literal {
    let f: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    xla::Literal::vec1(&f)
}

fn lit_mat(m: &Mat) -> xla::Literal {
    let f: Vec<f32> = m.data().iter().map(|&x| x as f32).collect();
    xla::Literal::vec1(&f)
        .reshape(&[m.rows() as i64, m.cols() as i64])
        .expect("reshape literal")
}

/// Pad a shard to `s_pad` rows; returns (x_pad, y_pad, mask).
fn pad_shard(sh: &Shard, s_pad: usize) -> (Mat, Vec<f64>, Vec<f64>) {
    let s = sh.s();
    let d = sh.x.cols();
    assert!(s_pad >= s);
    let mut x = Mat::zeros(s_pad, d);
    let mut y = vec![0.0; s_pad];
    let mut mask = vec![0.0; s_pad];
    for i in 0..s {
        x.row_mut(i).copy_from_slice(sh.x.row(i));
        y[i] = sh.y[i];
        mask[i] = 1.0;
    }
    (x, y, mask)
}

/// Linear-regression PJRT solver: `linear_setup` once (Gram assembly on
/// the Pallas kernel), native Cholesky inverse once, then the fused
/// `linear_update_{d}` artifact every iteration.
///
/// Perf (§Perf in EXPERIMENTS.md): all constant operands (`A^{-1}`,
/// `X^T y`, `rho`) are uploaded to device buffers once; each update
/// transfers only the two `d`-vectors that change.  The host-side copies
/// of `X`/`y` kept for loss evaluation are a one-time construction cost.
pub struct PjrtLinearSolver {
    ctx: Rc<PjrtContext>,
    exe: Rc<xla::PjRtLoadedExecutable>,
    update_name: String,
    a_inv_buf: xla::PjRtBuffer,
    xty_buf: xla::PjRtBuffer,
    rho_buf: xla::PjRtBuffer,
    scratch: Vec<f32>,
    d: usize,
    // native copies for loss evaluation
    x: Mat,
    y: Vec<f64>,
}

impl PjrtLinearSolver {
    pub fn new(
        ctx: Rc<PjrtContext>,
        sh: &Shard,
        rho: f64,
        degree: usize,
    ) -> Result<PjrtLinearSolver, String> {
        let d = sh.x.cols();
        let setup = ctx
            .manifest()
            .best_for_rows("linear_setup", sh.s(), d)
            .ok_or_else(|| format!("no linear_setup artifact for s>={} d={d}", sh.s()))?;
        let s_pad = setup.inputs[0].1[0];
        let setup_name = setup.name.clone();
        let (xp, yp, _) = pad_shard(sh, s_pad);
        let outs = ctx.execute(&setup_name, &[lit_mat(&xp), lit_vec(&yp)])?;
        let xtx_flat = &outs[0];
        let xty: Vec<f64> = outs[1].iter().map(|&v| v as f64).collect();
        let mut xtx = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                xtx[(i, j)] = xtx_flat[i * d + j] as f64;
            }
        }
        // one-time native inverse of A = X^T X + rho d_n I (setup path)
        let a = xtx.add_diag(rho * degree as f64);
        let a_inv = Cholesky::new(&a)
            .ok_or("A = X^T X + rho d I not SPD")?
            .inverse();
        let update_name = format!("linear_update_{d}");
        ctx.manifest()
            .by_name(&update_name)
            .ok_or_else(|| format!("no {update_name} artifact"))?;
        // warm the executable cache + stage constants off the hot path
        let exe = ctx.executable(&update_name)?;
        let a_inv_f32: Vec<f32> = a_inv.data().iter().map(|&v| v as f32).collect();
        let xty_f32: Vec<f32> = xty.iter().map(|&v| v as f32).collect();
        let a_inv_buf = ctx.upload(&a_inv_f32, &[d, d])?;
        let xty_buf = ctx.upload(&xty_f32, &[d])?;
        let rho_buf = ctx.upload(&[rho as f32], &[1])?;
        Ok(PjrtLinearSolver {
            ctx,
            exe,
            update_name,
            a_inv_buf,
            xty_buf,
            rho_buf,
            scratch: vec![0.0; d],
            d,
            x: sh.x.clone(),
            y: sh.y.clone(),
        })
    }

    fn upload_vec(&mut self, v: &[f64]) -> xla::PjRtBuffer {
        for (s, &x) in self.scratch.iter_mut().zip(v) {
            *s = x as f32;
        }
        self.ctx
            .upload(&self.scratch, &[self.d])
            .expect("upload vector")
    }
}

impl SubproblemSolver for PjrtLinearSolver {
    fn update_into(&mut self, alpha: &[f64], nbr_sum: &[f64], theta: &mut [f64]) {
        let alpha_buf = self.upload_vec(alpha);
        let nbr_buf = self.upload_vec(nbr_sum);
        let exe = self.exe.clone();
        let outs = self
            .ctx
            .execute_buffers(
                &exe,
                &self.update_name,
                &[
                    &self.a_inv_buf,
                    &self.xty_buf,
                    &alpha_buf,
                    &nbr_buf,
                    &self.rho_buf,
                ],
            )
            .expect("linear_update artifact failed");
        for (t, &v) in theta.iter_mut().zip(&outs[0]) {
            *t = v as f64;
        }
    }

    fn loss(&self, theta: &[f64]) -> f64 {
        let pred = self.x.matvec(theta);
        0.5 * pred
            .iter()
            .zip(&self.y)
            .map(|(p, y)| (p - y) * (p - y))
            .sum::<f64>()
    }

    fn d(&self) -> usize {
        self.d
    }

    fn set_degree(&mut self, _degree: usize) {
        // the degree is baked into the staged A^{-1} device constant;
        // churn is rejected for the PJRT backend at config validation
        unimplemented!("PJRT backend does not support churn (set_degree)");
    }
}

/// Logistic PJRT solver: fixed-budget Newton+CG artifact per iteration
/// (the Pallas `logistic_grad_hess` kernel fused inside).
///
/// Perf: the shard tensors (`x`, `y`, `mask`) and scalars are staged as
/// device buffers once; per update only `lin` and the warm start move.
pub struct PjrtLogisticSolver {
    ctx: Rc<PjrtContext>,
    exe: Rc<xla::PjRtLoadedExecutable>,
    newton_name: String,
    x_buf: xla::PjRtBuffer,
    y_buf: xla::PjRtBuffer,
    mask_buf: xla::PjRtBuffer,
    inv_count_buf: xla::PjRtBuffer,
    mu0_buf: xla::PjRtBuffer,
    rho_dn_buf: xla::PjRtBuffer,
    scratch: Vec<f32>,
    rho: f64,
    d: usize,
    // native copies for loss evaluation
    x: Mat,
    y: Vec<f64>,
    mu0: f64,
}

impl PjrtLogisticSolver {
    pub fn new(
        ctx: Rc<PjrtContext>,
        sh: &Shard,
        rho: f64,
        mu0: f64,
        degree: usize,
    ) -> Result<PjrtLogisticSolver, String> {
        let d = sh.x.cols();
        let spec = ctx
            .manifest()
            .best_for_rows("logistic_newton", sh.s(), d)
            .ok_or_else(|| format!("no logistic_newton artifact for s>={} d={d}", sh.s()))?;
        let s_pad = spec.inputs[0].1[0];
        let newton_name = spec.name.clone();
        let (xp, yp, mask) = pad_shard(sh, s_pad);
        let exe = ctx.executable(&newton_name)?;
        let xf: Vec<f32> = xp.data().iter().map(|&v| v as f32).collect();
        let yf: Vec<f32> = yp.iter().map(|&v| v as f32).collect();
        let mf: Vec<f32> = mask.iter().map(|&v| v as f32).collect();
        Ok(PjrtLogisticSolver {
            x_buf: ctx.upload(&xf, &[s_pad, d])?,
            y_buf: ctx.upload(&yf, &[s_pad])?,
            mask_buf: ctx.upload(&mf, &[s_pad])?,
            inv_count_buf: ctx.upload(&[1.0 / sh.s() as f32], &[1])?,
            mu0_buf: ctx.upload(&[mu0 as f32], &[1])?,
            rho_dn_buf: ctx.upload(&[(rho * degree as f64) as f32], &[1])?,
            ctx,
            exe,
            newton_name,
            scratch: vec![0.0; d],
            rho,
            d,
            x: sh.x.clone(),
            y: sh.y.clone(),
            mu0,
        })
    }

    fn upload_vec(&mut self, v: &[f64]) -> xla::PjRtBuffer {
        for (s, &x) in self.scratch.iter_mut().zip(v) {
            *s = x as f32;
        }
        self.ctx
            .upload(&self.scratch, &[self.d])
            .expect("upload vector")
    }
}

impl SubproblemSolver for PjrtLogisticSolver {
    fn update_into(&mut self, alpha: &[f64], nbr_sum: &[f64], theta: &mut [f64]) {
        let lin: Vec<f64> = alpha
            .iter()
            .zip(nbr_sum)
            .map(|(a, n)| a - self.rho * n)
            .collect();
        let lin_buf = self.upload_vec(&lin);
        // theta enters holding the warm start
        let warm_buf = self.upload_vec(theta);
        let exe = self.exe.clone();
        let outs = self
            .ctx
            .execute_buffers(
                &exe,
                &self.newton_name,
                &[
                    &self.x_buf,
                    &self.y_buf,
                    &self.mask_buf,
                    &self.inv_count_buf,
                    &self.mu0_buf,
                    &self.rho_dn_buf,
                    &lin_buf,
                    &warm_buf,
                ],
            )
            .expect("logistic_newton artifact failed");
        for (t, &v) in theta.iter_mut().zip(&outs[0]) {
            *t = v as f64;
        }
    }

    fn loss(&self, theta: &[f64]) -> f64 {
        let s = self.y.len();
        let mut acc = 0.0;
        for i in 0..s {
            let z = self.y[i] * crate::util::dot(self.x.row(i), theta);
            acc += if z > 0.0 {
                (-z).exp().ln_1p()
            } else {
                -z + z.exp().ln_1p()
            };
        }
        acc / s as f64 + 0.5 * self.mu0 * crate::util::dot(theta, theta)
    }

    fn d(&self) -> usize {
        self.d
    }

    fn set_degree(&mut self, _degree: usize) {
        // rho * degree is a staged device constant; churn is rejected
        // for the PJRT backend at config validation
        unimplemented!("PJRT backend does not support churn (set_degree)");
    }
}

// SAFETY: the PJRT CPU client is internally thread-safe, but our solver
// types share an Rc'd context, so cross-thread use is forbidden; the run
// engine enforces `threads == 1` for the PJRT backend (see
// `pjrt_solver`'s contract), making the Send bound a formality required
// by the `SubproblemSolver` trait object.
unsafe impl Send for PjrtLinearSolver {}
unsafe impl Send for PjrtLogisticSolver {}

thread_local! {
    /// Context cache per artifacts dir: one PJRT client + compiled
    /// executables shared by every worker's solver in a run.
    static CONTEXTS: RefCell<BTreeMap<std::path::PathBuf, Rc<PjrtContext>>> =
        const { RefCell::new(BTreeMap::new()) };
}

/// Get (or create) the shared PJRT context for an artifacts dir.
pub fn context_for(dir: &Path) -> Result<Rc<PjrtContext>, String> {
    CONTEXTS.with(|c| {
        let mut map = c.borrow_mut();
        if let Some(ctx) = map.get(dir) {
            return Ok(ctx.clone());
        }
        let ctx = Rc::new(PjrtContext::new(dir)?);
        map.insert(dir.to_path_buf(), ctx.clone());
        Ok(ctx)
    })
}

/// Factory: build the PJRT-backed solver for one worker's shard.
/// Contract: PJRT-backed runs must use `threads == 1`.
pub fn pjrt_solver(
    dir: &Path,
    task: Task,
    sh: &Shard,
    rho: f64,
    mu0: f64,
    degree: usize,
) -> Result<Box<dyn SubproblemSolver>, String> {
    let ctx = context_for(dir)?;
    match task {
        Task::Linear => Ok(Box::new(PjrtLinearSolver::new(ctx, sh, rho, degree)?)),
        Task::Logistic => Ok(Box::new(PjrtLogisticSolver::new(ctx, sh, rho, mu0, degree)?)),
    }
}
