//! Artifact manifest: a minimal JSON parser + the typed manifest the AOT
//! compiler (`python/compile/aot.py`) emits next to the HLO text files.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed JSON value (parser below; the *writer* lives in `crate::io`).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Recursive-descent JSON parser (full scalar/array/object grammar with
/// string escapes; numbers via `f64`).
pub fn parse_json(text: &str) -> Result<JsonValue, String> {
    let bytes: Vec<char> = text.chars().collect();
    let mut pos = 0usize;
    let v = parse_value(&bytes, &mut pos)?;
    skip_ws(&bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing garbage at char {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[char], pos: &mut usize) {
    while *pos < b.len() && b[*pos].is_whitespace() {
        *pos += 1;
    }
}

fn expect(b: &[char], pos: &mut usize, c: char) -> Result<(), String> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{c}' at char {pos}"))
    }
}

fn parse_value(b: &[char], pos: &mut usize) -> Result<JsonValue, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some('{') => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&'}') {
                *pos += 1;
                return Ok(JsonValue::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    JsonValue::Str(s) => s,
                    other => return Err(format!("object key must be string, got {other:?}")),
                };
                expect(b, pos, ':')?;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(',') => *pos += 1,
                    Some('}') => {
                        *pos += 1;
                        break;
                    }
                    _ => return Err(format!("expected ',' or '}}' at char {pos}")),
                }
            }
            Ok(JsonValue::Obj(map))
        }
        Some('[') => {
            *pos += 1;
            let mut arr = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&']') {
                *pos += 1;
                return Ok(JsonValue::Arr(arr));
            }
            loop {
                arr.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(',') => *pos += 1,
                    Some(']') => {
                        *pos += 1;
                        break;
                    }
                    _ => return Err(format!("expected ',' or ']' at char {pos}")),
                }
            }
            Ok(JsonValue::Arr(arr))
        }
        Some('"') => {
            *pos += 1;
            let mut s = String::new();
            loop {
                match b.get(*pos) {
                    None => return Err("unterminated string".into()),
                    Some('"') => {
                        *pos += 1;
                        break;
                    }
                    Some('\\') => {
                        *pos += 1;
                        match b.get(*pos) {
                            Some('n') => s.push('\n'),
                            Some('t') => s.push('\t'),
                            Some('r') => s.push('\r'),
                            Some('"') => s.push('"'),
                            Some('\\') => s.push('\\'),
                            Some('/') => s.push('/'),
                            Some('b') => s.push('\u{8}'),
                            Some('f') => s.push('\u{c}'),
                            Some('u') => {
                                let hex: String =
                                    b.get(*pos + 1..*pos + 5).unwrap_or(&[]).iter().collect();
                                let code = u32::from_str_radix(&hex, 16)
                                    .map_err(|_| format!("bad \\u escape at {pos}"))?;
                                s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                                *pos += 4;
                            }
                            other => return Err(format!("bad escape {other:?}")),
                        }
                        *pos += 1;
                    }
                    Some(c) => {
                        s.push(*c);
                        *pos += 1;
                    }
                }
            }
            Ok(JsonValue::Str(s))
        }
        Some('t') if b[*pos..].starts_with(&['t', 'r', 'u', 'e']) => {
            *pos += 4;
            Ok(JsonValue::Bool(true))
        }
        Some('f') if b[*pos..].starts_with(&['f', 'a', 'l', 's', 'e']) => {
            *pos += 5;
            Ok(JsonValue::Bool(false))
        }
        Some('n') if b[*pos..].starts_with(&['n', 'u', 'l', 'l']) => {
            *pos += 4;
            Ok(JsonValue::Null)
        }
        Some(_) => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], '0'..='9' | '-' | '+' | '.' | 'e' | 'E')
            {
                *pos += 1;
            }
            let tok: String = b[start..*pos].iter().collect();
            tok.parse::<f64>()
                .map(JsonValue::Num)
                .map_err(|_| format!("bad number '{tok}' at char {start}"))
        }
    }
}

/// One artifact's I/O signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub entry: String,
    pub file: PathBuf,
    /// (input name, shape) in call order.
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<String>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub row_block: usize,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` anchors the artifact file paths.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let root = parse_json(text)?;
        let row_block = root
            .get("row_block")
            .and_then(|v| v.as_f64())
            .ok_or("manifest missing row_block")? as usize;
        let mut artifacts = Vec::new();
        for a in root
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or("manifest missing artifacts")?
        {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or("artifact missing name")?
                .to_string();
            let entry = a
                .get("entry")
                .and_then(|v| v.as_str())
                .ok_or("artifact missing entry")?
                .to_string();
            let file = dir.join(
                a.get("file")
                    .and_then(|v| v.as_str())
                    .ok_or("artifact missing file")?,
            );
            let mut inputs = Vec::new();
            for i in a
                .get("inputs")
                .and_then(|v| v.as_arr())
                .ok_or("artifact missing inputs")?
            {
                let iname = i
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or("input missing name")?
                    .to_string();
                let shape: Vec<usize> = i
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or("input missing shape")?
                    .iter()
                    .map(|d| d.as_f64().unwrap_or(-1.0) as usize)
                    .collect();
                inputs.push((iname, shape));
            }
            let outputs: Vec<String> = a
                .get("outputs")
                .and_then(|v| v.as_arr())
                .ok_or("artifact missing outputs")?
                .iter()
                .filter_map(|o| o.as_str().map(|s| s.to_string()))
                .collect();
            artifacts.push(ArtifactSpec { name, entry, file, inputs, outputs });
        }
        Ok(Manifest { row_block, artifacts })
    }

    /// Find an artifact by exact name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find the smallest `entry` artifact whose first input is
    /// `(s_pad, d)` with `s_pad >= s_min` (shape selection for shards).
    pub fn best_for_rows(&self, entry: &str, s_min: usize, d: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.entry == entry)
            .filter(|a| {
                let shape = &a.inputs[0].1;
                shape.len() == 2 && shape[1] == d && shape[0] >= s_min
            })
            .min_by_key(|a| a.inputs[0].1[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_scalars_and_nesting() {
        let v = parse_json(r#"{"a": [1, -2.5e3, "x\n", true, null], "b": {"c": 7}}"#).unwrap();
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_f64(), Some(7.0));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(-2500.0));
        assert_eq!(arr[2].as_str(), Some("x\n"));
        assert_eq!(arr[3], JsonValue::Bool(true));
        assert_eq!(arr[4], JsonValue::Null);
    }

    #[test]
    fn json_rejects_garbage() {
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("12 34").is_err());
        assert!(parse_json(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn json_empty_containers() {
        assert_eq!(parse_json("[]").unwrap(), JsonValue::Arr(vec![]));
        assert_eq!(parse_json("{}").unwrap(), JsonValue::Obj(BTreeMap::new()));
    }

    #[test]
    fn manifest_parse_and_lookup() {
        let text = r#"{
            "format": "hlo-text", "dtype": "f32", "row_block": 8,
            "artifacts": [
                {"name": "linear_setup_16x14", "entry": "linear_setup",
                 "file": "linear_setup_16x14.hlo.txt",
                 "inputs": [{"name": "x", "shape": [16, 14]},
                            {"name": "y", "shape": [16]}],
                 "outputs": ["xtx", "xty"], "meta": {}},
                {"name": "linear_setup_56x50", "entry": "linear_setup",
                 "file": "linear_setup_56x50.hlo.txt",
                 "inputs": [{"name": "x", "shape": [56, 50]},
                            {"name": "y", "shape": [56]}],
                 "outputs": ["xtx", "xty"], "meta": {}}
            ]
        }"#;
        let m = Manifest::parse(text, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.row_block, 8);
        assert_eq!(m.artifacts.len(), 2);
        assert!(m.by_name("linear_setup_16x14").is_some());
        let best = m.best_for_rows("linear_setup", 14, 14).unwrap();
        assert_eq!(best.inputs[0].1, vec![16, 14]);
        assert!(m.best_for_rows("linear_setup", 100, 14).is_none());
        assert!(m.best_for_rows("linear_setup", 10, 99).is_none());
    }
}
