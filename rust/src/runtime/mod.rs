//! Artifact runtime: the manifest of AOT-compiled HLO artifacts plus the
//! PJRT execution backend that runs them on the per-iteration hot path.
//!
//! The [`manifest`] layer (always compiled) parses `manifest.json` and
//! describes every artifact's I/O signature.  The execution layer lives
//! in [`pjrt`] and is gated behind the `pjrt` cargo feature because it
//! needs the `xla` crate, which the offline build sandbox cannot fetch —
//! see rust/Cargo.toml for how to vendor it.  Without the feature,
//! [`pjrt_solver`] is a stub that reports a descriptive error, so
//! `Backend::Pjrt` fails loudly instead of at link time.

pub mod manifest;

pub use manifest::{ArtifactSpec, Manifest};

#[cfg(feature = "pjrt")]
mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::{context_for, pjrt_solver, PjrtContext, PjrtLinearSolver, PjrtLogisticSolver};

/// Stub for builds without the `pjrt` feature: constructing a PJRT-backed
/// solver always fails with an actionable message.  Signature-compatible
/// with [`pjrt::pjrt_solver`] so callers need no cfg of their own.
#[cfg(not(feature = "pjrt"))]
pub fn pjrt_solver(
    _dir: &std::path::Path,
    _task: crate::config::Task,
    _sh: &crate::data::Shard,
    _rho: f64,
    _mu0: f64,
    _degree: usize,
) -> Result<Box<dyn crate::solver::SubproblemSolver>, String> {
    Err(
        "this binary was built without the `pjrt` feature; rebuild with \
         `cargo build --features pjrt` (requires a vendored `xla` crate, \
         see rust/Cargo.toml and README.md §Building) or use the native \
         backend"
            .to_string(),
    )
}

#[cfg(test)]
mod tests {
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_reports_missing_feature() {
        use crate::data::{partition_uniform, synthetic};
        let ds = synthetic::linear_dataset(20, 3, 1);
        let shards = partition_uniform(&ds, 2, 1);
        let err = super::pjrt_solver(
            std::path::Path::new("artifacts"),
            crate::config::Task::Linear,
            &shards[0],
            1.0,
            0.0,
            1,
        )
        .err()
        .expect("stub must fail");
        assert!(err.contains("pjrt"), "{err}");
    }
}
