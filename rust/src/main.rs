//! `cq-ggadmm` — the launcher CLI.
//!
//! Subcommands regenerate every table/figure of the paper, run single
//! configurations (native or PJRT backend), inspect topologies and run the
//! threaded coordinator demo.  Run with `--help` for details.

use cq_ggadmm::algs::{AlgSpec, Problem, Run, RunOptions};
use cq_ggadmm::cli::{Args, Cli, Command};
use cq_ggadmm::config::{DatasetId, ExperimentConfig, TopologySpec};
use cq_ggadmm::coordinator::{Coordinator, CoordinatorOptions};
use cq_ggadmm::data;
use cq_ggadmm::experiments::{self, matrix, ExecOptions};
use cq_ggadmm::graph::{gen, spectral, Topology};
use cq_ggadmm::metrics::save_traces;
use cq_ggadmm::solver::Backend;
use std::path::PathBuf;
use std::process::ExitCode;

fn cli() -> Cli {
    Cli::new("cq-ggadmm", "CQ-GGADMM decentralized learning reproduction")
        .command(
            Command::new("exp", "regenerate a paper figure (fig2|fig3|fig4|fig5|fig6|all)")
                .opt("figure", Some("fig2"), "figure id")
                .opt("out", Some("results"), "output directory for CSV traces")
                .opt("backend", Some("native"), "native|pjrt")
                .opt("artifacts", Some("artifacts"), "artifacts dir (pjrt backend)")
                .opt("threads", Some("1"), "intra-run solver threads (native backend)")
                .opt("record-every", Some("1"), "trace sampling stride")
                .opt(
                    "sweep-threads",
                    Some("0"),
                    "concurrent runs (0 = all cores unless --threads > 1, 1 = serial driver)",
                )
                .switch("quiet", "suppress the summary tables"),
        )
        .command(
            Command::new("run", "run one algorithm on one dataset")
                .opt("dataset", Some("synth-linear"), "synth-linear|bodyfat|synth-logistic|derm")
                .opt("alg", Some("cq-ggadmm"), "ggadmm|c-ggadmm|q-ggadmm|cq-ggadmm|c-admm|gadmm|dgd")
                .opt("workers", Some("24"), "number of workers")
                .opt("connectivity", Some("0.3"), "graph connectivity ratio p")
                .opt(
                    "topology",
                    None,
                    "chain|ring|star|grid|torus|random[:p]|er[:p]|smallworld[:k,beta]|\
                     geometric[:r] (default: random:<connectivity>; gadmm defaults to chain)",
                )
                .opt("iters", Some("300"), "iterations")
                .opt("rho", Some("1.0"), "ADMM penalty rho")
                .opt("mu0", Some("0.01"), "logistic ridge mu0")
                .opt("tau0", Some("1.0"), "censoring threshold tau0")
                .opt("xi", Some("0.8"), "censoring decay xi")
                .opt("omega", Some("0.995"), "quantizer step decay omega")
                .opt("bits0", Some("2"), "initial quantizer bits")
                .opt("seed", Some("1"), "random seed")
                .opt("backend", Some("native"), "native|pjrt")
                .opt("artifacts", Some("artifacts"), "artifacts dir (pjrt backend)")
                .opt("config", None, "load parameters from a TOML config file")
                .opt("out", None, "write the trace CSV here"),
        )
        .command(
            Command::new("coordinator", "run the sharded-executor coordinator demo")
                .opt("dataset", Some("synth-linear"), "dataset id")
                .opt("alg", Some("cq-ggadmm"), "algorithm")
                .opt("workers", Some("12"), "number of workers")
                .opt("iters", Some("150"), "iterations")
                .opt("seed", Some("1"), "random seed")
                .opt("threads", Some("0"), "executor threads (0 = all cores)")
                .opt("drop-prob", Some("0"), "broadcast-erasure probability")
                .opt("topology", None, "topology family (see 'run --help'; default random:0.3)"),
        )
        .command(Command::new("datasets", "print Table 1 (dataset inventory)"))
        .command(
            Command::new("matrix", "run the (topology x algorithm) scenario matrix")
                .opt("dataset", Some("synth-linear"), "dataset id")
                .opt("workers", Some("24"), "number of workers")
                .opt("iters", Some("300"), "alternating-schedule iterations (Jacobian runs 4x)")
                .opt("seed", Some("1"), "random seed")
                .opt(
                    "families",
                    None,
                    "whitespace-separated topology specs (default: the standard family zoo)",
                )
                .opt("out", Some("results"), "output directory for CSV traces")
                .opt("backend", Some("native"), "native|pjrt")
                .opt("artifacts", Some("artifacts"), "artifacts dir (pjrt backend)")
                .opt("threads", Some("1"), "intra-run solver threads")
                .opt("record-every", Some("1"), "trace sampling stride")
                .opt("sweep-threads", Some("0"), "concurrent runs (0 = all cores)")
                .switch("quiet", "suppress the summary tables"),
        )
        .command(
            Command::new("rates", "empirical vs Theorem-3 convergence rates across densities")
                .opt("workers", Some("16"), "number of workers")
                .opt("iters", Some("150"), "iterations per study"),
        )
        .command(
            Command::new("sweep", "sensitivity/ablation sweeps (rho|tau0|bits|components)")
                .opt("study", Some("components"), "rho|tau0|bits|components")
                .opt("iters", Some("250"), "iterations per point")
                .opt("seed", Some("41"), "random seed"),
        )
        .command(
            Command::new("topo", "inspect a generated topology's spectral constants")
                .opt("workers", Some("18"), "number of workers")
                .opt("connectivity", Some("0.3"), "connectivity ratio")
                .opt("seed", Some("1"), "seed")
                .opt("topology", None, "topology family (see 'run --help'; default random:<p>)"),
        )
}

fn parse_alg(name: &str, a: &Args) -> Result<AlgSpec, String> {
    let tau0 = a.get_f64("tau0")?.unwrap_or(1.0);
    let xi = a.get_f64("xi")?.unwrap_or(0.8);
    let omega = a.get_f64("omega")?.unwrap_or(0.995);
    let bits0 = a.get_usize("bits0")?.unwrap_or(2) as u32;
    match name {
        "ggadmm" => Ok(AlgSpec::ggadmm()),
        "c-ggadmm" => Ok(AlgSpec::c_ggadmm(tau0, xi)),
        "q-ggadmm" => Ok(AlgSpec::q_ggadmm(omega, bits0)),
        "cq-ggadmm" => Ok(AlgSpec::cq_ggadmm(tau0, xi, omega, bits0)),
        "c-admm" => Ok(AlgSpec::c_admm(tau0, xi)),
        "gadmm" => Ok(AlgSpec::gadmm_chain()),
        _ => Err(format!("unknown algorithm '{name}'")),
    }
}

/// Resolve the effective topology: an explicit `--topology` flag wins,
/// then a config-file spec, then the legacy default (a chain for the
/// GADMM baseline, the paper's random-bipartite generator otherwise).
/// Returns the topology plus its label and the bipartition pass's
/// dropped-edge count.
fn build_topology(
    a: &Args,
    cfg_spec: Option<TopologySpec>,
    alg_name: &str,
    workers: usize,
    connectivity: f64,
    seed: u64,
) -> Result<(Topology, String, usize), String> {
    let spec = match a.get("topology") {
        Some(s) => Some(TopologySpec::parse(s)?),
        None => cfg_spec,
    };
    match spec {
        Some(spec) => {
            let b = gen::build(&spec, workers, seed)?;
            Ok((b.topology, spec.label(), b.dropped_edges))
        }
        None if alg_name == "gadmm" => Ok((Topology::chain(workers), "chain".into(), 0)),
        None => Ok((
            Topology::random_bipartite(workers, connectivity, seed),
            format!("random:{connectivity}"),
            0,
        )),
    }
}

fn exec_options(a: &Args) -> Result<ExecOptions, String> {
    let backend = Backend::parse(&a.get_or("backend", "native"))?;
    Ok(ExecOptions {
        backend,
        artifacts_dir: match backend {
            Backend::Pjrt => Some(PathBuf::from(a.get_or("artifacts", "artifacts"))),
            Backend::Native => None,
        },
        threads: a.get_usize("threads")?.unwrap_or(1),
        record_every: a.get_u64("record-every")?.unwrap_or(1),
        sweep_threads: a.get_usize("sweep-threads")?.unwrap_or(0),
    })
}

fn cmd_exp(a: &Args) -> Result<(), String> {
    let exec = exec_options(a)?;
    let out = PathBuf::from(a.get_or("out", "results"));
    let quiet = a.has("quiet");
    let figure = a.get_or("figure", "fig2");
    let ids: Vec<String> = if figure == "all" {
        vec!["fig2", "fig3", "fig4", "fig5", "fig6"]
            .into_iter()
            .map(String::from)
            .collect()
    } else {
        vec![figure]
    };
    // standard figures go through run_figures as ONE flattened job list
    // (the sweep scheduler saturates all cores across figure boundaries);
    // fig6's density variants are dispatched the same way afterwards
    let mut specs = Vec::new();
    let mut want_fig6 = false;
    for id in &ids {
        if id == "fig6" {
            want_fig6 = true;
        } else {
            specs.push(
                experiments::figure_by_id(id).ok_or_else(|| format!("unknown figure '{id}'"))?,
            );
        }
    }
    let save = |res: &experiments::FigureResult| -> Result<(), String> {
        let path = out.join(format!("{}.csv", res.id));
        save_traces(&res.traces, &path).map_err(|e| e.to_string())?;
        if !quiet {
            println!("\n=== {} ===\n{}", res.title, res.summary.render());
            println!("traces -> {}", path.display());
        }
        Ok(())
    };
    // the standard figures are one flattened sweep (results land together
    // when it returns); saving them before the fig6 sweep starts means a
    // fig6 failure cannot lose the figures that already finished
    for res in experiments::run_figures(&specs, &exec) {
        save(&res)?;
    }
    if want_fig6 {
        for res in experiments::run_fig6(&experiments::fig6(), &exec) {
            save(&res)?;
        }
    }
    Ok(())
}

fn cmd_run(a: &Args) -> Result<(), String> {
    // optional config file, overridden by explicit flags
    let mut cfg = match a.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
            ExperimentConfig::from_toml(&text)?
        }
        None => ExperimentConfig::default(),
    };
    if let Some(ds) = a.get("dataset") {
        cfg.dataset = DatasetId::parse(ds)?;
    }
    if let Some(w) = a.get_usize("workers")? {
        cfg.workers = w;
    }
    if let Some(p) = a.get_f64("connectivity")? {
        cfg.connectivity = p;
    }
    if let Some(v) = a.get_usize("iters")? {
        cfg.iters = v;
    }
    if let Some(v) = a.get_f64("rho")? {
        cfg.rho = v;
    }
    if let Some(v) = a.get_f64("mu0")? {
        cfg.mu0 = v;
    }
    if let Some(v) = a.get_u64("seed")? {
        cfg.seed = v;
    }
    cfg.validate()?;

    let alg_name = a.get_or("alg", "cq-ggadmm");
    let ds = data::load(cfg.dataset, cfg.seed);
    let (topo, topo_label, dropped) = build_topology(
        a,
        cfg.topology,
        &alg_name,
        cfg.workers,
        cfg.connectivity,
        cfg.seed,
    )?;
    let problem = Problem::new(&ds, &topo, cfg.rho, cfg.mu0, cfg.seed);
    println!(
        "dataset={} d={} workers={} topology={topo_label} edges={}{} f*={:.6e}",
        ds.name,
        problem.d,
        topo.n(),
        topo.edges().len(),
        if dropped > 0 {
            format!(" (bipartition dropped {dropped})")
        } else {
            String::new()
        },
        problem.f_star
    );

    let trace = if alg_name == "dgd" {
        cq_ggadmm::algs::dgd::run_dgd(
            &problem,
            &topo,
            0.01,
            cfg.iters as u64,
            cq_ggadmm::comm::EnergyParams::default(),
        )
    } else {
        let spec = parse_alg(&alg_name, a)?;
        let backend = Backend::parse(&a.get_or("backend", "native"))?;
        let opts = RunOptions {
            backend,
            threads: cfg.threads.max(1),
            seed: cfg.seed,
            record_every: 1,
            artifacts_dir: match backend {
                Backend::Pjrt => Some(PathBuf::from(a.get_or("artifacts", "artifacts"))),
                Backend::Native => None,
            },
            ..RunOptions::default()
        };
        let mut run = Run::new(problem, topo, spec, opts);
        run.run(cfg.iters as u64)
    };

    let last = trace.points.last().expect("no trace points");
    println!(
        "{}: iters={} gap={:.3e} rounds={} bits={} energy={:.3e} J",
        trace.algorithm,
        last.iteration,
        last.loss_gap,
        last.cum_rounds,
        last.cum_bits,
        last.cum_energy_j
    );
    for target in [1e-4, 1e-6] {
        if let Some(p) = trace.first_below(target) {
            println!(
                "  -> {target:.0e} at iter={} rounds={} bits={} energy={:.3e} J",
                p.iteration, p.cum_rounds, p.cum_bits, p.cum_energy_j
            );
        }
    }
    if let Some(path) = a.get("out") {
        trace
            .save_csv(std::path::Path::new(path))
            .map_err(|e| e.to_string())?;
        println!("trace -> {path}");
    }
    Ok(())
}

fn cmd_coordinator(a: &Args) -> Result<(), String> {
    let dataset = DatasetId::parse(&a.get_or("dataset", "synth-linear"))?;
    let workers = a.get_usize("workers")?.unwrap_or(12);
    let iters = a.get_u64("iters")?.unwrap_or(150);
    let seed = a.get_u64("seed")?.unwrap_or(1);
    let threads = a.get_usize("threads")?.unwrap_or(0);
    let drop_prob = a.get_f64("drop-prob")?.unwrap_or(0.0);
    let spec = parse_alg(&a.get_or("alg", "cq-ggadmm"), a)?;
    let alg_name = spec.name.clone();
    let ds = data::load(dataset, seed);
    let (topo, topo_label, _) = build_topology(a, None, "", workers, 0.3, seed)?;
    let problem = Problem::new(&ds, &topo, 1.0, 1e-2, seed);
    let coord = Coordinator::spawn(
        problem,
        topo,
        spec,
        CoordinatorOptions { seed, threads, drop_prob, ..CoordinatorOptions::default() },
    );
    println!(
        "sharding {} workers ({topo_label}) over a {}-thread executor, algorithm {alg_name}",
        workers,
        coord.threads(),
    );
    let trace = coord.run(iters);
    let last = trace.points.last().unwrap();
    println!(
        "{}: iters={} gap={:.3e} rounds={} bits={} energy={:.3e} J",
        trace.algorithm,
        last.iteration,
        last.loss_gap,
        last.cum_rounds,
        last.cum_bits,
        last.cum_energy_j
    );
    Ok(())
}

fn cmd_matrix(a: &Args) -> Result<(), String> {
    let exec = exec_options(a)?;
    let dataset = DatasetId::parse(&a.get_or("dataset", "synth-linear"))?;
    let workers = a.get_usize("workers")?.unwrap_or(24);
    let iters = a.get_u64("iters")?.unwrap_or(300);
    let seed = a.get_u64("seed")?.unwrap_or(1);
    let quiet = a.has("quiet");
    let out = PathBuf::from(a.get_or("out", "results"));
    let mut spec = matrix::default_matrix(dataset, workers, iters, seed);
    if let Some(list) = a.get("families") {
        let families: Result<Vec<TopologySpec>, String> =
            list.split_whitespace().map(TopologySpec::parse).collect();
        spec.families = families?;
        if spec.families.is_empty() {
            return Err("--families: no topology specs given".into());
        }
    }
    if !quiet {
        println!(
            "topology properties (N={workers}, seed={seed}):\n{}",
            matrix::properties_table(workers, &spec.families, seed)?.render()
        );
    }
    let results = matrix::run_matrix(&spec, &exec)?;
    let mut all = Vec::new();
    for fr in &results {
        if !quiet {
            println!(
                "\n=== {} (edges={}, dropped={}) ===\n{}",
                fr.label,
                fr.edges,
                fr.dropped_edges,
                fr.summary.render()
            );
        }
        all.extend(fr.traces.iter().cloned());
    }
    let path = out.join("topology_matrix.csv");
    save_traces(&all, &path).map_err(|e| e.to_string())?;
    if !quiet {
        println!("\ntraces -> {}", path.display());
    }
    Ok(())
}

fn cmd_rates(a: &Args) -> Result<(), String> {
    let workers = a.get_usize("workers")?.unwrap_or(16);
    let iters = a.get_u64("iters")?.unwrap_or(150);
    let studies = experiments::rates::study(&[0.15, 0.3, 0.5, 0.8], workers, 11, iters);
    println!("{}", experiments::rates::render(&studies).render());
    Ok(())
}

fn cmd_sweep(a: &Args) -> Result<(), String> {
    use cq_ggadmm::experiments::sensitivity as sens;
    let iters = a.get_u64("iters")?.unwrap_or(250);
    let seed = a.get_u64("seed")?.unwrap_or(41);
    let study = a.get_or("study", "components");
    let (title, points) = match study.as_str() {
        "rho" => (
            "rho",
            sens::rho_sweep(&[0.5, 2.0, 10.0, 30.0, 100.0], iters, seed),
        ),
        "tau0" => (
            "tau0",
            sens::tau0_sweep(&[0.0, 0.05, 0.1, 0.5, 5.0, 50.0], 0.9, iters, seed),
        ),
        "bits" => ("bits0", sens::bits_sweep(&[2, 4, 8, 12], iters, seed)),
        "components" => ("component", sens::component_ablation(iters, seed)),
        other => return Err(format!("unknown study '{other}'")),
    };
    println!("{}", sens::render(title, &points).render());
    Ok(())
}

fn cmd_topo(a: &Args) -> Result<(), String> {
    let workers = a.get_usize("workers")?.unwrap_or(18);
    let p = a.get_f64("connectivity")?.unwrap_or(0.3);
    let seed = a.get_u64("seed")?.unwrap_or(1);
    let (topo, topo_label, dropped) = build_topology(a, None, "", workers, p, seed)?;
    let consts = spectral::constants(&topo);
    println!(
        "topology={topo_label} workers={} edges={} dropped={dropped} ratio={:.3} heads={} tails={}",
        topo.n(),
        topo.edges().len(),
        topo.connectivity_ratio(),
        topo.heads().len(),
        topo.tails().len()
    );
    println!(
        "sigma_max(C)={:.4} sigma_max(M-)={:.4} sigma~_min(M-)={:.4}",
        consts.sigma_max_c, consts.sigma_max_m_minus, consts.sigma_min_nz_m_minus
    );
    for i in 0..topo.n() {
        println!(
            "  worker {i:>2} [{}] degree {} neighbors {:?}",
            match topo.group(i) {
                cq_ggadmm::graph::Group::Head => "H",
                cq_ggadmm::graph::Group::Tail => "T",
            },
            topo.degree(i),
            topo.neighbors(i)
        );
    }
    Ok(())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            if e.is_help {
                println!("{}", e.message);
                return ExitCode::SUCCESS;
            }
            eprintln!("error: {}", e.message);
            return ExitCode::FAILURE;
        }
    };
    let result = match args.command.as_str() {
        "exp" => cmd_exp(&args),
        "run" => cmd_run(&args),
        "coordinator" => cmd_coordinator(&args),
        "datasets" => {
            println!("{}", experiments::table1().render());
            Ok(())
        }
        "matrix" => cmd_matrix(&args),
        "rates" => cmd_rates(&args),
        "sweep" => cmd_sweep(&args),
        "topo" => cmd_topo(&args),
        other => Err(format!("unhandled command {other}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
