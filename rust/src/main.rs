//! `cq-ggadmm` — the launcher CLI.
//!
//! Subcommands regenerate every table/figure of the paper, run single
//! configurations (native or PJRT backend), inspect topologies and run the
//! threaded coordinator demo.  Run with `--help` for details.
//!
//! Every subcommand accepts `--manifest <file>`: a layered TOML document
//! ([`cq_ggadmm::config::ExperimentManifest`]) carrying the problem,
//! algorithm, execution, link and output configuration.  Explicit CLI
//! flags override manifest values; without a manifest the flag defaults
//! reproduce the legacy CLI exactly.  `run`, `coordinator` and `serve`
//! also support run directories (`--run-dir`), periodic checkpoints
//! (`--checkpoint-every`), bit-identical resume (`--resume`) and
//! streaming JSONL event logs (`--events`).  `serve` + `worker` run the
//! same protocol over TCP (see README §Networked mode).

use cq_ggadmm::algs::{AlgSpec, Problem, Run};
use cq_ggadmm::cli::{Args, Cli, Command};
use cq_ggadmm::config::{DatasetId, ExperimentConfig, ExperimentManifest, ModelSpec, TopologySpec};
use cq_ggadmm::param::BitsSpec;
use cq_ggadmm::coordinator::Coordinator;
use cq_ggadmm::data;
use cq_ggadmm::experiments::{self, matrix, ExecOptions};
use cq_ggadmm::graph::{gen, spectral, ChurnSchedule, Topology};
use cq_ggadmm::io::{checkpoint, run_with_persistence, JsonlSink, RunDir};
use cq_ggadmm::metrics::{save_traces, Trace};
use cq_ggadmm::net;
use cq_ggadmm::solver::Backend;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn cli() -> Cli {
    Cli::new("cq-ggadmm", "CQ-GGADMM decentralized learning reproduction")
        .command(
            Command::new("exp", "regenerate a paper figure (fig2|fig3|fig4|fig5|fig6|all)")
                .opt("figure", Some("fig2"), "figure id")
                .opt("manifest", None, "layered TOML manifest (flags override)")
                .opt("out", Some("results"), "output directory for CSV traces")
                .opt("run-dir", None, "emit into a runs/<NNNN-slug>/ directory under this base")
                .opt("backend", Some("native"), "native|pjrt")
                .opt("artifacts", Some("artifacts"), "artifacts dir (pjrt backend)")
                .opt("threads", Some("1"), "intra-run solver threads (native backend)")
                .opt("record-every", Some("1"), "trace sampling stride")
                .opt(
                    "sweep-threads",
                    Some("0"),
                    "concurrent runs (0 = all cores unless --threads > 1, 1 = serial driver)",
                )
                .opt("kernel-tier", None, "kernel tier: scalar|avx2|auto (default: env/detect)")
                .switch("quiet", "suppress the summary tables"),
        )
        .command(
            Command::new("run", "run one algorithm on one dataset")
                .opt("dataset", Some("synth-linear"), "synth-linear|bodyfat|synth-logistic|derm")
                .opt(
                    "alg",
                    Some("cq-ggadmm"),
                    "ggadmm|c-ggadmm|q-ggadmm|cq-ggadmm|c-admm|gadmm|qdgd|dgd",
                )
                .opt("model", None, "model: glm|mlp[:hidden] (mlp is the two-block layer-wise MLP)")
                .opt("workers", Some("24"), "number of workers")
                .opt("connectivity", Some("0.3"), "graph connectivity ratio p")
                .opt(
                    "topology",
                    None,
                    "chain|ring|star|grid|torus|random[:p]|er[:p]|smallworld[:k,beta]|\
                     geometric[:r] (default: random:<connectivity>; gadmm defaults to chain)",
                )
                .opt("iters", Some("300"), "iterations")
                .opt("rho", Some("1.0"), "ADMM penalty rho")
                .opt("mu0", Some("0.01"), "logistic ridge mu0")
                .opt("tau0", Some("1.0"), "censoring threshold tau0")
                .opt("xi", Some("0.8"), "censoring decay xi")
                .opt("omega", Some("0.995"), "quantizer step decay omega")
                .opt("bits0", Some("2"), "initial quantizer bits: one width or per-block 'N,M' (e.g. 24,8)")
                .opt("seed", Some("1"), "random seed")
                .opt("backend", Some("native"), "native|pjrt")
                .opt("artifacts", Some("artifacts"), "artifacts dir (pjrt backend)")
                .opt("config", None, "legacy: load [experiment] keys from a TOML file")
                .opt("manifest", None, "layered TOML manifest (flags override)")
                .opt("run-dir", None, "create a runs/<NNNN-slug>/ directory under this base")
                .opt("resume", None, "resume from this run directory's checkpoint")
                .opt("checkpoint-every", None, "checkpoint cadence in iterations (0 = final only)")
                .opt("events", None, "stream JSONL events to this path (default: run dir)")
                .opt("out", None, "write the trace CSV here")
                .opt("churn", None, "worker-churn schedule: '<at>:<leave|join>:<worker> ...'")
                .opt("staleness", None, "bounded-staleness refresh threshold (rounds)")
                .opt("kernel-tier", None, "kernel tier: scalar|avx2|auto (default: env/detect)"),
        )
        .command(
            Command::new("coordinator", "run the sharded-executor coordinator demo")
                .opt("dataset", Some("synth-linear"), "dataset id")
                .opt("alg", Some("cq-ggadmm"), "algorithm")
                .opt("model", None, "model: glm|mlp[:hidden] (mlp is the two-block layer-wise MLP)")
                .opt("workers", Some("12"), "number of workers")
                .opt("iters", Some("150"), "iterations")
                .opt("seed", Some("1"), "random seed")
                .opt("threads", Some("0"), "executor threads (0 = all cores)")
                .opt("drop-prob", Some("0"), "broadcast-erasure probability")
                .opt("tau0", Some("1.0"), "censoring threshold tau0")
                .opt("xi", Some("0.8"), "censoring decay xi")
                .opt("omega", Some("0.995"), "quantizer step decay omega")
                .opt("bits0", Some("2"), "initial quantizer bits: one width or per-block 'N,M' (e.g. 24,8)")
                .opt("topology", None, "topology family (see 'run --help'; default random:0.3)")
                .opt("manifest", None, "layered TOML manifest (flags override)")
                .opt("run-dir", None, "create a runs/<NNNN-slug>/ directory under this base")
                .opt("resume", None, "resume from this run directory's checkpoint")
                .opt("checkpoint-every", None, "checkpoint cadence in iterations (0 = final only)")
                .opt("events", None, "stream JSONL events to this path (default: run dir)")
                .opt("churn", None, "worker-churn schedule: '<at>:<leave|join>:<worker> ...'")
                .opt("staleness", None, "bounded-staleness refresh threshold (rounds)")
                .opt("kernel-tier", None, "kernel tier: scalar|avx2|auto (default: env/detect)"),
        )
        .command(
            Command::new("serve", "run the coordinator as a TCP server (pair with 'worker')")
                .opt("bind", Some("127.0.0.1"), "listen address")
                .opt("port", Some("0"), "listen port (0 = ephemeral)")
                .opt("port-file", None, "write the bound port here (atomically) once listening")
                .opt("dataset", Some("synth-linear"), "dataset id")
                .opt("alg", Some("cq-ggadmm"), "algorithm")
                .opt("model", None, "model: glm|mlp[:hidden] (mlp is the two-block layer-wise MLP)")
                .opt("workers", Some("12"), "number of workers")
                .opt("connectivity", Some("0.3"), "graph connectivity ratio p")
                .opt("iters", Some("150"), "iterations")
                .opt("seed", Some("1"), "random seed")
                .opt("drop-prob", Some("0"), "broadcast-erasure probability")
                .opt("tau0", Some("1.0"), "censoring threshold tau0")
                .opt("xi", Some("0.8"), "censoring decay xi")
                .opt("omega", Some("0.995"), "quantizer step decay omega")
                .opt("bits0", Some("2"), "initial quantizer bits: one width or per-block 'N,M' (e.g. 24,8)")
                .opt("topology", None, "topology family (see 'run --help'; default random:0.3)")
                .opt("manifest", None, "layered TOML manifest (flags override)")
                .opt("run-dir", None, "create a runs/<NNNN-slug>/ directory under this base")
                .opt("resume", None, "resume from this run directory's checkpoint")
                .opt("checkpoint-every", None, "checkpoint cadence in iterations (0 = final only)")
                .opt("events", None, "stream JSONL events to this path (default: run dir)")
                .opt("churn", None, "worker-churn schedule: '<at>:<leave|join>:<worker> ...'")
                .opt("staleness", None, "bounded-staleness refresh threshold (rounds)")
                .opt("kernel-tier", None, "kernel tier: scalar|avx2|auto (default: env/detect)"),
        )
        .command(
            Command::new("worker", "host one or more workers of a 'serve' run over TCP")
                .opt("connect", None, "server address, e.g. 127.0.0.1:4800 (required)")
                .opt("ids", None, "worker id or half-open range, e.g. '7' or '0..16' (required)")
                .opt("exit-after-iter", None, "depart cleanly after completing this iteration")
                .opt("kernel-tier", None, "kernel tier: scalar|avx2|auto (default: env/detect)"),
        )
        .command(
            Command::new("datasets", "print Table 1 (dataset inventory)")
                .opt("manifest", None, "layered TOML manifest (validated; the table is static)"),
        )
        .command(
            Command::new("matrix", "run the (topology x algorithm) scenario matrix")
                .opt("dataset", Some("synth-linear"), "dataset id")
                .opt("workers", Some("24"), "number of workers")
                .opt("iters", Some("300"), "alternating-schedule iterations (Jacobian runs 4x)")
                .opt("seed", Some("1"), "random seed")
                .opt(
                    "families",
                    None,
                    "whitespace-separated topology specs (default: the standard family zoo)",
                )
                .opt("manifest", None, "layered TOML manifest (flags override)")
                .opt("out", Some("results"), "output directory for CSV traces")
                .opt("run-dir", None, "emit into a runs/<NNNN-slug>/ directory under this base")
                .opt("backend", Some("native"), "native|pjrt")
                .opt("artifacts", Some("artifacts"), "artifacts dir (pjrt backend)")
                .opt("threads", Some("1"), "intra-run solver threads")
                .opt("record-every", Some("1"), "trace sampling stride")
                .opt("sweep-threads", Some("0"), "concurrent runs (0 = all cores)")
                .opt("kernel-tier", None, "kernel tier: scalar|avx2|auto (default: env/detect)")
                .switch("quiet", "suppress the summary tables"),
        )
        .command(
            Command::new(
                "churn-matrix",
                "run the (churn x straggler x topology x algorithm) robustness matrix",
            )
            .opt("dataset", Some("synth-linear"), "dataset id")
            .opt("workers", Some("24"), "number of workers")
            .opt("iters", Some("300"), "iterations per cell")
            .opt("seed", Some("1"), "random seed")
            .opt(
                "families",
                None,
                "whitespace-separated topology specs (default: chain torus smallworld:4,0.1)",
            )
            .opt("churn-rates", None, "comma-separated churned-worker fractions (default: 0,0.5,1)")
            .opt("straggler-fracs", None, "comma-separated straggler fractions (default: 0,0.25)")
            .opt("staleness", None, "bounded-staleness refresh threshold (default: 4)")
            .opt("manifest", None, "layered TOML manifest (flags override)")
            .opt("out", Some("results"), "output directory for the degradation CSV")
            .opt("run-dir", None, "emit into a runs/<NNNN-slug>/ directory under this base")
            .opt("threads", Some("1"), "intra-run solver threads")
            .opt("sweep-threads", Some("0"), "concurrent runs (0 = all cores)")
            .opt("kernel-tier", None, "kernel tier: scalar|avx2|auto (default: env/detect)")
            .switch("quiet", "suppress the summary table"),
        )
        .command(
            Command::new("rates", "empirical vs Theorem-3 convergence rates across densities")
                .opt("manifest", None, "layered TOML manifest (flags override)")
                .opt("workers", Some("16"), "number of workers")
                .opt("iters", Some("150"), "iterations per study")
                .opt("kernel-tier", None, "kernel tier: scalar|avx2|auto (default: env/detect)"),
        )
        .command(
            Command::new("sweep", "sensitivity/ablation sweeps (rho|tau0|bits|bits-split|components)")
                .opt("study", Some("components"), "rho|tau0|bits|bits-split|components")
                .opt("manifest", None, "layered TOML manifest (flags override)")
                .opt("iters", Some("250"), "iterations per point")
                .opt("seed", Some("41"), "random seed")
                .opt("kernel-tier", None, "kernel tier: scalar|avx2|auto (default: env/detect)"),
        )
        .command(
            Command::new("topo", "inspect a generated topology's spectral constants")
                .opt("manifest", None, "layered TOML manifest (flags override)")
                .opt("workers", Some("18"), "number of workers")
                .opt("connectivity", Some("0.3"), "connectivity ratio")
                .opt("seed", Some("1"), "seed")
                .opt("topology", None, "topology family (see 'run --help'; default random:<p>)"),
        )
}

/// Resolve a subcommand's layered configuration: `--manifest` (or legacy
/// `--config`, or a resumed run's stamped manifest) first, then flags.
/// Explicit flags always override the file; when no file is given, the
/// declared flag defaults apply — reproducing the legacy CLI exactly.
fn resolve_manifest(a: &Args) -> Result<ExperimentManifest, String> {
    let mut from_file = true;
    let mut m = if let Some(path) = a.get("manifest") {
        ExperimentManifest::load(Path::new(path))?
    } else if let Some(dir) = a.get("resume") {
        // a resumed run replays the configuration it was started with
        let stamped = Path::new(dir).join("manifest.toml");
        if stamped.is_file() {
            ExperimentManifest::load(&stamped)?
        } else {
            from_file = false;
            ExperimentManifest::default()
        }
    } else if let Some(path) = a.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let e = ExperimentConfig::from_toml(&text)?;
        let mut m = ExperimentManifest::default();
        m.exec = m.exec.with_seed(e.seed).with_threads(e.threads);
        m.experiment = e;
        m
    } else {
        from_file = false;
        ExperimentManifest::default()
    };
    // `take(flag)`: explicit flags always win; flag *defaults* only apply
    // when no file set the value
    let take = |name: &str| a.given(name) || !from_file;
    if take("dataset") {
        if let Some(v) = a.get("dataset") {
            m.experiment.dataset = DatasetId::parse(v)?;
        }
    }
    if take("workers") {
        if let Some(v) = a.get_usize("workers")? {
            m.experiment.workers = v;
        }
    }
    if take("connectivity") {
        if let Some(v) = a.get_f64("connectivity")? {
            m.experiment.connectivity = v;
        }
    }
    if take("iters") {
        if let Some(v) = a.get_usize("iters")? {
            m.experiment.iters = v;
        }
    }
    if take("rho") {
        if let Some(v) = a.get_f64("rho")? {
            m.experiment.rho = v;
        }
    }
    if take("mu0") {
        if let Some(v) = a.get_f64("mu0")? {
            m.experiment.mu0 = v;
        }
    }
    if take("seed") {
        if let Some(v) = a.get_u64("seed")? {
            m.experiment.seed = v;
            m.exec.seed = v;
        }
    }
    if take("tau0") {
        if let Some(v) = a.get_f64("tau0")? {
            m.experiment.tau0 = v;
        }
    }
    if take("xi") {
        if let Some(v) = a.get_f64("xi")? {
            m.experiment.xi = v;
        }
    }
    if take("omega") {
        if let Some(v) = a.get_f64("omega")? {
            m.experiment.omega = v;
        }
    }
    if take("bits0") {
        if let Some(v) = a.get("bits0") {
            // per-block grammar: '24,8' allocates one width per model
            // block; a single width resets any manifest split
            let spec = BitsSpec::parse(v).map_err(|err| format!("option --bits0: {err}"))?;
            m.experiment.bits0 = spec.per_block[0];
            m.experiment.bits_split =
                if spec.is_uniform() { None } else { Some(spec.per_block.clone()) };
        }
    }
    if take("model") {
        if let Some(v) = a.get("model") {
            m.experiment.model =
                Some(ModelSpec::parse(v).map_err(|err| format!("option --model: {err}"))?);
        }
    }
    if take("topology") {
        if let Some(v) = a.get("topology") {
            m.experiment.topology = Some(TopologySpec::parse(v)?);
        }
    }
    if take("alg") {
        if let Some(v) = a.get("alg") {
            m.alg = v.to_string();
        }
    }
    if take("backend") {
        if let Some(v) = a.get("backend") {
            m.exec.backend = Backend::parse(v)?;
        }
    }
    if m.exec.backend == Backend::Pjrt && (a.given("artifacts") || m.exec.artifacts_dir.is_none())
    {
        m.exec.artifacts_dir = Some(PathBuf::from(a.get_or("artifacts", "artifacts")));
    }
    if take("threads") {
        if let Some(v) = a.get_usize("threads")? {
            m.exec.threads = v;
        }
    }
    if take("sweep-threads") {
        if let Some(v) = a.get_usize("sweep-threads")? {
            m.exec.sweep_threads = v;
        }
    }
    if take("record-every") {
        if let Some(v) = a.get_u64("record-every")? {
            m.exec.record_every = v;
        }
    }
    if take("drop-prob") {
        if let Some(v) = a.get_f64("drop-prob")? {
            m.exec.drop_prob = v;
        }
    }
    if let Some(v) = a.get("churn") {
        m.exec.churn = Some(ChurnSchedule::parse(v)?);
    }
    if let Some(v) = a.get_u64("staleness")? {
        m.exec.staleness_bound = Some(v);
    }
    if let Some(v) = a.get("run-dir") {
        m.output.dir = Some(PathBuf::from(v));
    }
    if let Some(v) = a.get("checkpoint-every") {
        m.output.checkpoint_every = v
            .parse::<u64>()
            .map_err(|_| format!("option --checkpoint-every: expected an integer, got '{v}'"))?;
    }
    m.validate()?;
    Ok(m)
}

/// Build the manifest's topology: an explicit family spec wins; the
/// legacy default is a chain for the GADMM baseline and the paper's
/// random-bipartite generator otherwise.  Returns the topology plus its
/// label and the bipartition pass's dropped-edge count.
fn build_topology(m: &ExperimentManifest) -> Result<(Topology, String, usize), String> {
    let e = &m.experiment;
    match e.topology {
        Some(spec) => {
            let b = gen::build(&spec, e.workers, e.seed)?;
            Ok((b.topology, spec.label(), b.dropped_edges))
        }
        None if m.alg == "gadmm" => Ok((Topology::chain(e.workers), "chain".into(), 0)),
        None => Ok((
            Topology::random_bipartite(e.workers, e.connectivity, e.seed),
            format!("random:{}", e.connectivity),
            0,
        )),
    }
}

/// The persistence layout of a `run` / `coordinator` invocation.
struct Persistence {
    dir: RunDir,
    resuming: bool,
}

/// Resolve `--resume` / `--run-dir` / `[output] dir` into a run
/// directory (a fresh one gets the resolved manifest stamped in).
fn resolve_persistence(a: &Args, m: &ExperimentManifest) -> Result<Option<Persistence>, String> {
    if let Some(dir) = a.get("resume") {
        let dir = RunDir::open(Path::new(dir)).map_err(|e| e.to_string())?;
        return Ok(Some(Persistence { dir, resuming: true }));
    }
    let Some(base) = &m.output.dir else {
        return Ok(None);
    };
    let slug = format!("{}-{}", m.alg, m.experiment.dataset.name());
    let dir = RunDir::create(base, &slug).map_err(|e| e.to_string())?;
    dir.write_manifest(&m.to_toml()).map_err(|e| e.to_string())?;
    Ok(Some(Persistence { dir, resuming: false }))
}

fn print_trace_summary(trace: &Trace) {
    let last = trace.points.last().expect("no trace points");
    println!(
        "{}: iters={} gap={:.3e} rounds={} bits={} energy={:.3e} J",
        trace.algorithm,
        last.iteration,
        last.loss_gap,
        last.cum_rounds,
        last.cum_bits,
        last.cum_energy_j
    );
}

fn cmd_exp(a: &Args) -> Result<(), String> {
    let m = resolve_manifest(a)?;
    let exec: ExecOptions = m.exec.clone();
    let quiet = a.has("quiet");
    let figure = a.get_or("figure", "fig2");
    // result routing: a run directory when requested, the legacy flat
    // CSV directory otherwise
    let run_dir = match &m.output.dir {
        Some(base) => {
            let dir = RunDir::create(base, &format!("exp-{figure}"))
                .map_err(|e| e.to_string())?;
            dir.write_manifest(&m.to_toml()).map_err(|e| e.to_string())?;
            Some(dir)
        }
        None => None,
    };
    let out = PathBuf::from(a.get_or("out", "results"));
    let ids: Vec<String> = if figure == "all" {
        vec!["fig2", "fig3", "fig4", "fig5", "fig6"]
            .into_iter()
            .map(String::from)
            .collect()
    } else {
        vec![figure.clone()]
    };
    // standard figures go through run_figures as ONE flattened job list
    // (the sweep scheduler saturates all cores across figure boundaries);
    // fig6's density variants are dispatched the same way afterwards
    let mut specs = Vec::new();
    let mut want_fig6 = false;
    for id in &ids {
        if id == "fig6" {
            want_fig6 = true;
        } else {
            specs.push(
                experiments::figure_by_id(id).ok_or_else(|| format!("unknown figure '{id}'"))?,
            );
        }
    }
    let save = |res: &experiments::FigureResult| -> Result<(), String> {
        let path = match &run_dir {
            Some(dir) => dir.artifact(&format!("{}.csv", res.id)),
            None => out.join(format!("{}.csv", res.id)),
        };
        save_traces(&res.traces, &path).map_err(|e| e.to_string())?;
        if !quiet {
            println!("\n=== {} ===\n{}", res.title, res.summary.render());
            println!("traces -> {}", path.display());
        }
        Ok(())
    };
    // the standard figures are one flattened sweep (results land together
    // when it returns); saving them before the fig6 sweep starts means a
    // fig6 failure cannot lose the figures that already finished
    for res in experiments::run_figures(&specs, &exec) {
        save(&res)?;
    }
    if want_fig6 {
        for res in experiments::run_fig6(&experiments::fig6(), &exec) {
            save(&res)?;
        }
    }
    Ok(())
}

fn cmd_run(a: &Args) -> Result<(), String> {
    let m = resolve_manifest(a)?;
    let e = &m.experiment;
    let ds = data::load(e.dataset, e.seed);
    let (topo, topo_label, dropped) = build_topology(&m)?;
    let model = e.model.unwrap_or(ModelSpec::Glm);
    let problem = Problem::with_model(&ds, &topo, e.rho, e.mu0, e.seed, model)?;
    println!(
        "dataset={} d={} workers={} topology={topo_label} edges={}{} f*={:.6e}",
        ds.name,
        problem.d,
        topo.n(),
        topo.edges().len(),
        if dropped > 0 {
            format!(" (bipartition dropped {dropped})")
        } else {
            String::new()
        },
        problem.f_star
    );

    let persist = resolve_persistence(a, &m)?;
    let iters = e.iters as u64;
    let trace = if m.alg == "dgd" {
        if persist.as_ref().is_some_and(|p| p.resuming) || a.get("events").is_some() {
            return Err("dgd does not support checkpoint/resume or event streaming".into());
        }
        if model != ModelSpec::Glm {
            return Err("dgd is a single-block GLM baseline; use --model glm (or --alg qdgd)".into());
        }
        let trace = cq_ggadmm::algs::dgd::run_dgd(
            &problem,
            &topo,
            0.01,
            iters,
            cq_ggadmm::comm::EnergyParams::default(),
        );
        if let Some(p) = &persist {
            p.dir.save_trace(&trace).map_err(|err| err.to_string())?;
            println!("run dir -> {}", p.dir.path().display());
        }
        trace
    } else {
        let spec = AlgSpec::parse(&m.alg, e.tau0, e.xi, e.omega, e.bits0)?
            .with_bits_split(e.bits_split.clone());
        spec.validate()?;
        let mut run = Run::new(problem, topo, spec, m.exec.clone());
        match &persist {
            Some(p) => {
                let events = match a.get("events") {
                    Some(path) => PathBuf::from(path),
                    None => p.dir.events_path(),
                };
                if p.resuming {
                    let state = checkpoint::load(&p.dir.checkpoint_path())
                        .map_err(|err| format!("cannot load checkpoint: {err}"))?;
                    run.restore_state(&state);
                    run.resume_event_log(Box::new(
                        JsonlSink::append(&events).map_err(|err| err.to_string())?,
                    ));
                    println!("resumed at iteration {}", run.iteration());
                } else {
                    run.start_event_log(Box::new(
                        JsonlSink::create(&events).map_err(|err| err.to_string())?,
                    ));
                }
                let remaining = iters.saturating_sub(run.iteration());
                run_with_persistence(&mut run, remaining, &p.dir, m.output.checkpoint_every)
                    .map_err(|err| err.to_string())?;
                p.dir.save_trace(run.trace()).map_err(|err| err.to_string())?;
                println!("run dir -> {}", p.dir.path().display());
                run.trace().clone()
            }
            None => {
                if let Some(path) = a.get("events") {
                    run.start_event_log(Box::new(
                        JsonlSink::create(Path::new(path)).map_err(|err| err.to_string())?,
                    ));
                }
                run.run(iters)
            }
        }
    };

    print_trace_summary(&trace);
    for target in [1e-4, 1e-6] {
        if let Some(p) = trace.first_below(target) {
            println!(
                "  -> {target:.0e} at iter={} rounds={} bits={} energy={:.3e} J",
                p.iteration, p.cum_rounds, p.cum_bits, p.cum_energy_j
            );
        }
    }
    if let Some(path) = a.get("out") {
        trace
            .save_csv(Path::new(path))
            .map_err(|err| err.to_string())?;
        println!("trace -> {path}");
    }
    Ok(())
}

fn cmd_coordinator(a: &Args) -> Result<(), String> {
    let m = resolve_manifest(a)?;
    if m.exec.backend != Backend::Native {
        return Err("the coordinator shards native solvers only; use backend = \"native\"".into());
    }
    if m.alg == "dgd" {
        return Err("dgd is a first-order baseline; use 'run --alg dgd'".into());
    }
    let e = &m.experiment;
    let spec = AlgSpec::parse(&m.alg, e.tau0, e.xi, e.omega, e.bits0)?
        .with_bits_split(e.bits_split.clone());
    spec.validate()?;
    let alg_name = spec.name.clone();
    let ds = data::load(e.dataset, e.seed);
    let (topo, topo_label, _) = build_topology(&m)?;
    let problem = Problem::with_model(&ds, &topo, e.rho, e.mu0, e.seed, e.model.unwrap_or(ModelSpec::Glm))?;
    let mut coord = Coordinator::spawn(problem, topo, spec, m.exec.clone());
    println!(
        "sharding {} workers ({topo_label}) over a {}-thread executor, algorithm {alg_name}",
        e.workers,
        coord.threads(),
    );
    let iters = e.iters as u64;
    let persist = resolve_persistence(a, &m)?;
    let trace = match &persist {
        Some(p) => {
            let events = match a.get("events") {
                Some(path) => PathBuf::from(path),
                None => p.dir.events_path(),
            };
            if p.resuming {
                let state = checkpoint::load(&p.dir.checkpoint_path())
                    .map_err(|err| format!("cannot load checkpoint: {err}"))?;
                coord.restore_state(&state);
                coord.resume_event_log(Box::new(
                    JsonlSink::append(&events).map_err(|err| err.to_string())?,
                ));
                println!("resumed at iteration {}", coord.iteration());
            } else {
                coord.start_event_log(Box::new(
                    JsonlSink::create(&events).map_err(|err| err.to_string())?,
                ));
            }
            let remaining = iters.saturating_sub(coord.iteration());
            run_with_persistence(&mut coord, remaining, &p.dir, m.output.checkpoint_every)
                .map_err(|err| err.to_string())?;
            p.dir.save_trace(coord.trace()).map_err(|err| err.to_string())?;
            println!("run dir -> {}", p.dir.path().display());
            coord.trace().clone()
        }
        None => {
            if let Some(path) = a.get("events") {
                coord.start_event_log(Box::new(
                    JsonlSink::create(Path::new(path)).map_err(|err| err.to_string())?,
                ));
            }
            coord.run(iters)
        }
    };
    print_trace_summary(&trace);
    Ok(())
}

/// Publish the bound port for test harnesses and launch scripts: write
/// to a temp file, then rename — a reader never sees a partial write.
fn write_port_file(path: &Path, port: u16) -> Result<(), String> {
    let tmp = path.with_extension("port.tmp");
    std::fs::write(&tmp, format!("{port}\n")).map_err(|e| e.to_string())?;
    std::fs::rename(&tmp, path).map_err(|e| e.to_string())?;
    Ok(())
}

fn cmd_serve(a: &Args) -> Result<(), String> {
    let m = resolve_manifest(a)?;
    if m.exec.backend != Backend::Native {
        return Err("the networked coordinator runs native solvers only".into());
    }
    if m.alg == "dgd" {
        return Err("dgd is a first-order baseline; use 'run --alg dgd'".into());
    }
    let (problem, topo, spec) = net::build_session(&m)?;
    let alg_name = spec.name.clone();
    let bind = a.get_or("bind", "127.0.0.1");
    let port = a.get_or("port", "0");
    let mut coord = net::server::NetCoordinator::bind(
        problem,
        topo,
        spec,
        m.exec.clone(),
        m.to_toml(),
        &format!("{bind}:{port}"),
    )
    .map_err(|e| format!("cannot bind {bind}:{port}: {e}"))?;
    let addr = coord.local_addr();
    println!(
        "serving {} workers on {addr}, algorithm {alg_name}",
        m.experiment.workers
    );
    if let Some(path) = a.get("port-file") {
        write_port_file(Path::new(path), addr.port())?;
    }
    let iters = m.experiment.iters as u64;
    let persist = resolve_persistence(a, &m)?;
    let trace = match &persist {
        Some(p) => {
            let events = match a.get("events") {
                Some(path) => PathBuf::from(path),
                None => p.dir.events_path(),
            };
            if p.resuming {
                let state = checkpoint::load(&p.dir.checkpoint_path())
                    .map_err(|err| format!("cannot load checkpoint: {err}"))?;
                coord.restore_state(&state);
                coord.resume_event_log(Box::new(
                    JsonlSink::append(&events).map_err(|err| err.to_string())?,
                ));
                println!("resumed at iteration {}", coord.iteration());
            } else {
                coord.start_event_log(Box::new(
                    JsonlSink::create(&events).map_err(|err| err.to_string())?,
                ));
            }
            coord.wait_for_fleet();
            let remaining = iters.saturating_sub(coord.iteration());
            run_with_persistence(&mut coord, remaining, &p.dir, m.output.checkpoint_every)
                .map_err(|err| err.to_string())?;
            p.dir.save_trace(&coord.trace()).map_err(|err| err.to_string())?;
            println!("run dir -> {}", p.dir.path().display());
            coord.trace()
        }
        None => {
            if let Some(path) = a.get("events") {
                coord.start_event_log(Box::new(
                    JsonlSink::create(Path::new(path)).map_err(|err| err.to_string())?,
                ));
            }
            coord.wait_for_fleet();
            coord.run(iters)
        }
    };
    coord.shutdown();
    print_trace_summary(&trace);
    Ok(())
}

fn cmd_worker(a: &Args) -> Result<(), String> {
    let connect = a
        .get("connect")
        .ok_or("worker: --connect <host:port> is required")?
        .to_string();
    let ids = net::client::parse_ids(a.get("ids").ok_or("worker: --ids is required")?)?;
    let opts = net::client::WorkerOptions {
        connect,
        ids,
        exit_after_iter: a.get_u64("exit-after-iter")?,
    };
    net::client::run_worker(&opts)
}

fn cmd_matrix(a: &Args) -> Result<(), String> {
    let m = resolve_manifest(a)?;
    let exec: ExecOptions = m.exec.clone();
    let e = &m.experiment;
    let quiet = a.has("quiet");
    let run_dir = match &m.output.dir {
        Some(base) => {
            let dir = RunDir::create(base, "matrix").map_err(|err| err.to_string())?;
            dir.write_manifest(&m.to_toml()).map_err(|err| err.to_string())?;
            Some(dir)
        }
        None => None,
    };
    let out = PathBuf::from(a.get_or("out", "results"));
    let mut spec = matrix::default_matrix(e.dataset, e.workers, e.iters as u64, e.seed);
    if let Some(list) = a.get("families") {
        let families: Result<Vec<TopologySpec>, String> =
            list.split_whitespace().map(TopologySpec::parse).collect();
        spec.families = families?;
        if spec.families.is_empty() {
            return Err("--families: no topology specs given".into());
        }
    }
    if !quiet {
        println!(
            "topology properties (N={}, seed={}):\n{}",
            e.workers,
            e.seed,
            matrix::properties_table(e.workers, &spec.families, e.seed)?.render()
        );
    }
    let results = matrix::run_matrix(&spec, &exec)?;
    let mut all = Vec::new();
    for fr in &results {
        if !quiet {
            println!(
                "\n=== {} (edges={}, dropped={}) ===\n{}",
                fr.label,
                fr.edges,
                fr.dropped_edges,
                fr.summary.render()
            );
        }
        all.extend(fr.traces.iter().cloned());
    }
    let path = match &run_dir {
        Some(dir) => dir.artifact("topology_matrix.csv"),
        None => out.join("topology_matrix.csv"),
    };
    save_traces(&all, &path).map_err(|err| err.to_string())?;
    if !quiet {
        println!("\ntraces -> {}", path.display());
    }
    Ok(())
}

fn cmd_churn_matrix(a: &Args) -> Result<(), String> {
    let m = resolve_manifest(a)?;
    let exec: ExecOptions = m.exec.clone();
    let e = &m.experiment;
    let quiet = a.has("quiet");
    let run_dir = match &m.output.dir {
        Some(base) => {
            let dir = RunDir::create(base, "churn-matrix").map_err(|err| err.to_string())?;
            dir.write_manifest(&m.to_toml()).map_err(|err| err.to_string())?;
            Some(dir)
        }
        None => None,
    };
    let out = PathBuf::from(a.get_or("out", "results"));
    let mut spec = matrix::default_churn_matrix(e.dataset, e.workers, e.iters as u64, e.seed);
    if let Some(list) = a.get("families") {
        let families: Result<Vec<TopologySpec>, String> =
            list.split_whitespace().map(TopologySpec::parse).collect();
        spec.families = families?;
        if spec.families.is_empty() {
            return Err("--families: no topology specs given".into());
        }
    }
    let parse_fracs = |flag: &str| -> Result<Option<Vec<f64>>, String> {
        match a.get(flag) {
            None => Ok(None),
            Some(list) => list
                .split(',')
                .map(|v| {
                    v.trim()
                        .parse::<f64>()
                        .map_err(|_| format!("option --{flag}: expected a number, got '{v}'"))
                })
                .collect::<Result<Vec<f64>, String>>()
                .map(Some),
        }
    };
    if let Some(rates) = parse_fracs("churn-rates")? {
        spec.churn_rates = rates;
    }
    if let Some(fracs) = parse_fracs("straggler-fracs")? {
        spec.straggler_fracs = fracs;
    }
    if let Some(v) = a.get_u64("staleness")? {
        spec.staleness_bound = Some(v);
    }
    let cells = matrix::run_churn_matrix(&spec, &exec)?;
    if !quiet {
        println!("{}", matrix::churn_summary(&cells, spec.target_gap).render());
    }
    let csv = matrix::churn_matrix_csv(&cells, spec.target_gap);
    let path = match &run_dir {
        Some(dir) => dir.artifact("churn_matrix.csv"),
        None => out.join("churn_matrix.csv"),
    };
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|err| err.to_string())?;
    }
    std::fs::write(&path, csv).map_err(|err| err.to_string())?;
    if !quiet {
        println!("\ndegradation surface -> {}", path.display());
    }
    Ok(())
}

fn cmd_rates(a: &Args) -> Result<(), String> {
    let m = resolve_manifest(a)?;
    let workers = m.experiment.workers;
    let iters = m.experiment.iters as u64;
    let studies = experiments::rates::study(&[0.15, 0.3, 0.5, 0.8], workers, 11, iters);
    println!("{}", experiments::rates::render(&studies).render());
    Ok(())
}

fn cmd_sweep(a: &Args) -> Result<(), String> {
    use cq_ggadmm::experiments::sensitivity as sens;
    let m = resolve_manifest(a)?;
    let iters = m.experiment.iters as u64;
    let seed = m.experiment.seed;
    let study = a.get_or("study", "components");
    let (title, points) = match study.as_str() {
        "rho" => (
            "rho",
            sens::rho_sweep(&[0.5, 2.0, 10.0, 30.0, 100.0], iters, seed),
        ),
        "tau0" => (
            "tau0",
            sens::tau0_sweep(&[0.0, 0.05, 0.1, 0.5, 5.0, 50.0], 0.9, iters, seed),
        ),
        "bits" => ("bits0", sens::bits_sweep(&[2, 4, 8, 12], iters, seed)),
        "bits-split" => (
            "W,v allocation",
            sens::bits_alloc_sweep(
                &[
                    vec![8, 8],
                    vec![12, 4],
                    vec![4, 12],
                    vec![24, 8],
                    vec![2, 2],
                ],
                8,
                iters,
                1e-3,
                seed,
            ),
        ),
        "components" => ("component", sens::component_ablation(iters, seed)),
        other => return Err(format!("unknown study '{other}'")),
    };
    println!("{}", sens::render(title, &points).render());
    Ok(())
}

fn cmd_topo(a: &Args) -> Result<(), String> {
    let m = resolve_manifest(a)?;
    let (topo, topo_label, dropped) = build_topology(&m)?;
    let consts = spectral::constants(&topo);
    println!(
        "topology={topo_label} workers={} edges={} dropped={dropped} ratio={:.3} heads={} tails={}",
        topo.n(),
        topo.edges().len(),
        topo.connectivity_ratio(),
        topo.heads().len(),
        topo.tails().len()
    );
    println!(
        "sigma_max(C)={:.4} sigma_max(M-)={:.4} sigma~_min(M-)={:.4}",
        consts.sigma_max_c, consts.sigma_max_m_minus, consts.sigma_min_nz_m_minus
    );
    for i in 0..topo.n() {
        println!(
            "  worker {i:>2} [{}] degree {} neighbors {:?}",
            match topo.group(i) {
                cq_ggadmm::graph::Group::Head => "H",
                cq_ggadmm::graph::Group::Tail => "T",
            },
            topo.degree(i),
            topo.neighbors(i)
        );
    }
    Ok(())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            if e.is_help {
                println!("{}", e.message);
                return ExitCode::SUCCESS;
            }
            eprintln!("error: {}", e.message);
            return ExitCode::FAILURE;
        }
    };
    // Pin the linalg kernel tier before any dense work runs: the flag
    // beats the CQ_KERNEL_TIER env var, which beats runtime detection.
    if let Some(v) = args.get("kernel-tier") {
        match cq_ggadmm::util::tier::apply_tier_override(v) {
            Ok(t) => eprintln!("kernel tier: {t}"),
            Err(e) => {
                eprintln!("error: --kernel-tier: {e}");
                return ExitCode::FAILURE;
            }
        }
    }
    let result = match args.command.as_str() {
        "exp" => cmd_exp(&args),
        "run" => cmd_run(&args),
        "coordinator" => cmd_coordinator(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "datasets" => resolve_manifest(&args).map(|_| {
            println!("{}", experiments::table1().render());
        }),
        "matrix" => cmd_matrix(&args),
        "churn-matrix" => cmd_churn_matrix(&args),
        "rates" => cmd_rates(&args),
        "sweep" => cmd_sweep(&args),
        "topo" => cmd_topo(&args),
        other => Err(format!("unhandled command {other}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
