//! The experiment manifest: one layered TOML document that subsumes the
//! CLI flag sprawl — problem parameters, algorithm + quantizer/censor
//! knobs, topology family, link model, execution layout (threads, sweep
//! parallelism, backend) and output/checkpoint policy.
//!
//! Layering: every key is optional and defaults to the same value the
//! bare CLI would use; explicit CLI flags override manifest values (the
//! CLI applies them *after* loading).  `to_toml` serializes the fully
//! resolved configuration — that is what [`crate::io::RunDir`] stamps
//! into each run directory as `manifest.toml`, and
//! `parse(to_toml(m)) == m` holds exactly (property-tested below).
//!
//! Sections:
//!
//! ```toml
//! [experiment]      # ExperimentConfig + `alg`
//! dataset = "synth-linear"
//! alg = "cq-ggadmm"
//! workers = 24
//! topology = "smallworld:6,0.2"
//! # ... rho, mu0, iters, seed, tau0, xi, omega, bits0, threads
//!
//! [exec]            # ExecutionConfig overrides
//! threads = 4
//! sweep_threads = 0
//! backend = "native"
//! record_every = 1
//! incremental = true
//!
//! [link]
//! model = "erasure:0.2"   # ideal | erasure:<p> | latency:<base>,<per_bit>
//! drop_prob = 0.0         # legacy shorthand when `model` is absent
//!
//! [energy]
//! total_bandwidth_hz = 2e6
//! n0_w_per_hz = 1e-6
//! slot_s = 1e-3
//!
//! [churn]                     # dynamic-network policy (omit = static graph)
//! schedule = "10:leave:3 40:join:3"   # ChurnSchedule::parse grammar
//! staleness_bound = 4         # force a refresh after this many silent rounds
//!
//! [output]
//! dir = "runs"            # run-directory base (omit = no run dir)
//! checkpoint_every = 50   # iterations; 0 = only the final checkpoint
//! ```

use super::exec::ExecutionConfig;
use super::{parse_toml, ExperimentConfig, TopologySpec};
use crate::comm::LinkKind;
use crate::graph::ChurnSchedule;
use crate::solver::Backend;
use std::fmt::Write as _;
use std::path::PathBuf;

/// Algorithm names a manifest accepts (`dgd` is the first-order
/// baseline; the rest construct an `AlgSpec` — keep in sync with
/// `AlgSpec::parse`).
pub const ALG_NAMES: &[&str] =
    &["ggadmm", "c-ggadmm", "q-ggadmm", "cq-ggadmm", "c-admm", "gadmm", "qdgd", "dgd"];

/// Output / persistence policy of a run.
#[derive(Clone, Debug, PartialEq)]
pub struct OutputConfig {
    /// Run-directory base (`runs/<NNNN-slug>/...`); `None` = no run dir.
    pub dir: Option<PathBuf>,
    /// Checkpoint cadence in iterations; 0 = only the final checkpoint.
    pub checkpoint_every: u64,
}

impl Default for OutputConfig {
    fn default() -> Self {
        OutputConfig { dir: None, checkpoint_every: 0 }
    }
}

/// The full resolved configuration of one experiment.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentManifest {
    pub experiment: ExperimentConfig,
    /// Algorithm name (see [`ALG_NAMES`]).
    pub alg: String,
    pub exec: ExecutionConfig,
    pub output: OutputConfig,
}

impl Default for ExperimentManifest {
    fn default() -> Self {
        let experiment = ExperimentConfig::default();
        // the execution layer inherits the experiment's seed and thread
        // request unless [exec] overrides them
        let exec = ExecutionConfig::default()
            .with_seed(experiment.seed)
            .with_threads(experiment.threads);
        ExperimentManifest {
            experiment,
            alg: "cq-ggadmm".into(),
            exec,
            output: OutputConfig::default(),
        }
    }
}

impl ExperimentManifest {
    /// Parse a manifest document.  Unknown sections are ignored (forward
    /// compatibility); unknown values inside known keys error.
    pub fn from_toml(text: &str) -> Result<ExperimentManifest, String> {
        let experiment = ExperimentConfig::from_toml(text)?;
        let doc = parse_toml(text)?;
        let sec = if doc.sections.contains_key("experiment") { "experiment" } else { "" };
        let mut m = ExperimentManifest::default();
        m.exec = m
            .exec
            .with_seed(experiment.seed)
            .with_threads(experiment.threads);
        m.experiment = experiment;
        if let Some(alg) = doc.get_str(sec, "alg")? {
            m.alg = alg;
        }
        if let Some(v) = doc.get_usize("exec", "threads")? {
            m.exec.threads = v;
        }
        if let Some(v) = doc.get_usize("exec", "sweep_threads")? {
            m.exec.sweep_threads = v;
        }
        if let Some(s) = doc.get_str("exec", "backend")? {
            m.exec.backend = Backend::parse(&s)?;
        }
        if let Some(s) = doc.get_str("exec", "artifacts_dir")? {
            m.exec.artifacts_dir = Some(PathBuf::from(s));
        }
        if let Some(v) = doc.get_usize("exec", "record_every")? {
            m.exec.record_every = v as u64;
        }
        if let Some(v) = doc.get_bool("exec", "incremental")? {
            m.exec.incremental = v;
        }
        if let Some(s) = doc.get_str("link", "model")? {
            m.exec.link = Some(LinkKind::parse(&s)?);
        }
        if let Some(v) = doc.get_f64("link", "drop_prob")? {
            m.exec.drop_prob = v;
        }
        if let Some(v) = doc.get_f64("energy", "total_bandwidth_hz")? {
            m.exec.energy.total_bandwidth_hz = v;
        }
        if let Some(v) = doc.get_f64("energy", "n0_w_per_hz")? {
            m.exec.energy.n0_w_per_hz = v;
        }
        if let Some(v) = doc.get_f64("energy", "slot_s")? {
            m.exec.energy.slot_s = v;
        }
        if let Some(s) = doc.get_str("churn", "schedule")? {
            m.exec.churn = Some(ChurnSchedule::parse(&s)?);
        }
        if let Some(v) = doc.get_usize("churn", "staleness_bound")? {
            m.exec.staleness_bound = Some(v as u64);
        }
        if let Some(s) = doc.get_str("output", "dir")? {
            m.output.dir = Some(PathBuf::from(s));
        }
        if let Some(v) = doc.get_usize("output", "checkpoint_every")? {
            m.output.checkpoint_every = v as u64;
        }
        m.validate()?;
        Ok(m)
    }

    /// Load a manifest file.
    pub fn load(path: &std::path::Path) -> Result<ExperimentManifest, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read manifest {}: {e}", path.display()))?;
        ExperimentManifest::from_toml(&text)
            .map_err(|e| format!("manifest {}: {e}", path.display()))
    }

    /// Validate the cross-layer constraints on top of the per-struct ones.
    pub fn validate(&self) -> Result<(), String> {
        self.experiment.validate()?;
        self.exec.validate()?;
        if !ALG_NAMES.contains(&self.alg.as_str()) {
            return Err(format!(
                "unknown algorithm '{}' (expected one of {})",
                self.alg,
                ALG_NAMES.join("|")
            ));
        }
        Ok(())
    }

    /// Serialize the fully resolved configuration.  `{}` formatting of
    /// `f64` round-trips exactly through the parser, so
    /// `from_toml(to_toml(m))` reproduces `m` bit-for-bit.
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        let e = &self.experiment;
        let _ = writeln!(s, "[experiment]");
        let _ = writeln!(s, "dataset = \"{}\"", e.dataset.name());
        let _ = writeln!(s, "alg = \"{}\"", self.alg);
        let _ = writeln!(s, "workers = {}", e.workers);
        let _ = writeln!(s, "connectivity = {}", e.connectivity);
        if let Some(t) = &e.topology {
            let _ = writeln!(s, "topology = \"{}\"", t.label());
        }
        if let Some(m) = &e.model {
            let _ = writeln!(s, "model = \"{}\"", m.label());
        }
        let _ = writeln!(s, "rho = {}", e.rho);
        let _ = writeln!(s, "mu0 = {}", e.mu0);
        let _ = writeln!(s, "iters = {}", e.iters);
        let _ = writeln!(s, "seed = {}", e.seed);
        let _ = writeln!(s, "tau0 = {}", e.tau0);
        let _ = writeln!(s, "xi = {}", e.xi);
        let _ = writeln!(s, "omega = {}", e.omega);
        match &e.bits_split {
            None => {
                let _ = writeln!(s, "bits0 = {}", e.bits0);
            }
            Some(split) => {
                let spec = crate::param::BitsSpec { per_block: split.clone() };
                let _ = writeln!(s, "bits0 = \"{}\"", spec.label());
            }
        }
        let _ = writeln!(s, "threads = {}", e.threads);
        let x = &self.exec;
        let _ = writeln!(s, "\n[exec]");
        let _ = writeln!(s, "threads = {}", x.threads);
        let _ = writeln!(s, "sweep_threads = {}", x.sweep_threads);
        let _ = writeln!(
            s,
            "backend = \"{}\"",
            match x.backend {
                Backend::Native => "native",
                Backend::Pjrt => "pjrt",
            }
        );
        if let Some(dir) = &x.artifacts_dir {
            let _ = writeln!(s, "artifacts_dir = \"{}\"", dir.display());
        }
        let _ = writeln!(s, "record_every = {}", x.record_every);
        let _ = writeln!(s, "incremental = {}", x.incremental);
        let _ = writeln!(s, "\n[link]");
        if let Some(link) = &x.link {
            let _ = writeln!(s, "model = \"{}\"", link.label());
        }
        let _ = writeln!(s, "drop_prob = {}", x.drop_prob);
        let _ = writeln!(s, "\n[energy]");
        let _ = writeln!(s, "total_bandwidth_hz = {}", x.energy.total_bandwidth_hz);
        let _ = writeln!(s, "n0_w_per_hz = {}", x.energy.n0_w_per_hz);
        let _ = writeln!(s, "slot_s = {}", x.energy.slot_s);
        if x.churn.is_some() || x.staleness_bound.is_some() {
            let _ = writeln!(s, "\n[churn]");
            if let Some(c) = &x.churn {
                let _ = writeln!(s, "schedule = \"{}\"", c.label());
            }
            if let Some(t) = x.staleness_bound {
                let _ = writeln!(s, "staleness_bound = {t}");
            }
        }
        let _ = writeln!(s, "\n[output]");
        if let Some(dir) = &self.output.dir {
            let _ = writeln!(s, "dir = \"{}\"", dir.display());
        }
        let _ = writeln!(s, "checkpoint_every = {}", self.output.checkpoint_every);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetId;

    fn assert_round_trips(m: &ExperimentManifest) {
        let text = m.to_toml();
        let back = ExperimentManifest::from_toml(&text)
            .unwrap_or_else(|e| panic!("serialized manifest must re-parse: {e}\n{text}"));
        assert_eq!(&back, m, "round trip changed the manifest:\n{text}");
        // parse -> serialize -> parse is a fixpoint
        assert_eq!(back.to_toml(), text);
    }

    #[test]
    fn default_round_trips() {
        assert_round_trips(&ExperimentManifest::default());
    }

    #[test]
    fn round_trip_property_over_knob_space() {
        // sweep a spread of awkward values through every layer: floats
        // that need shortest-repr printing, optional fields present and
        // absent, every link model and backendless knob
        let links = [
            None,
            Some(LinkKind::Ideal),
            Some(LinkKind::Erasure { p: 0.17 }),
            Some(LinkKind::Latency { base_s: 1.5e-3, per_bit_s: 1e-9 }),
        ];
        let topologies = [
            None,
            Some(TopologySpec::SmallWorld { k: 6, beta: 0.2 }),
            Some(TopologySpec::Geometric { radius_m: 151.25 }),
        ];
        let mut case = 0u64;
        for link in &links {
            for topo in &topologies {
                case += 1;
                let mut m = ExperimentManifest::default();
                m.alg = ALG_NAMES[(case as usize) % ALG_NAMES.len()].to_string();
                m.experiment.dataset = DatasetId::Derm;
                m.experiment.workers = 10 + case as usize;
                m.experiment.connectivity = 0.1 + 0.07 * case as f64;
                m.experiment.rho = 0.30000000000000004 * case as f64; // classic non-representable
                m.experiment.mu0 = 1e-2 / 3.0;
                m.experiment.seed = 1 << case;
                m.experiment.tau0 = case as f64 * 0.1;
                m.experiment.xi = 1.0 - 1.0 / (case + 2) as f64;
                m.experiment.omega = 0.995;
                m.experiment.topology = *topo;
                m.exec.seed = m.experiment.seed;
                m.exec.threads = case as usize % 5;
                m.exec.sweep_threads = (case as usize + 1) % 3;
                m.exec.record_every = 1 + case % 7;
                m.exec.incremental = case % 2 == 0;
                m.exec.link = *link;
                m.exec.drop_prob = if link.is_none() { 0.125 } else { 0.0 };
                if case % 3 == 0 {
                    m.exec.churn =
                        Some(ChurnSchedule::parse("4:leave:2 9:join:2").unwrap());
                    m.exec.staleness_bound = Some(1 + case % 6);
                }
                m.exec.energy.slot_s = 1e-3 * (1.0 + case as f64 / 7.0);
                m.output.dir = if case % 2 == 0 { Some(PathBuf::from("runs")) } else { None };
                m.output.checkpoint_every = case * 10;
                assert_round_trips(&m);
            }
        }
        assert!(case >= 12, "property sweep must cover the grid");
    }

    #[test]
    fn bits_split_and_model_round_trip() {
        let mut m = ExperimentManifest::default();
        m.alg = "qdgd".into();
        m.experiment.model = Some(crate::config::ModelSpec::Mlp { hidden: 5 });
        m.experiment.bits0 = 24;
        m.experiment.bits_split = Some(vec![24, 8]);
        assert_round_trips(&m);
        // ... and the serialized form uses the string bits-spec grammar
        assert!(m.to_toml().contains("bits0 = \"24,8\""), "{}", m.to_toml());
        assert!(m.to_toml().contains("model = \"mlp:5\""), "{}", m.to_toml());
    }

    #[test]
    fn layering_experiment_seed_and_threads_flow_into_exec() {
        let m = ExperimentManifest::from_toml(
            r#"
            [experiment]
            seed = 99
            threads = 3
            "#,
        )
        .unwrap();
        assert_eq!(m.exec.seed, 99);
        assert_eq!(m.exec.threads, 3);
        // ... and [exec] wins over [experiment] when both are given
        let m = ExperimentManifest::from_toml(
            r#"
            [experiment]
            seed = 99
            threads = 3
            [exec]
            threads = 8
            "#,
        )
        .unwrap();
        assert_eq!(m.exec.threads, 8);
        assert_eq!(m.experiment.threads, 3);
    }

    #[test]
    fn link_and_output_sections_parse() {
        let m = ExperimentManifest::from_toml(
            r#"
            [experiment]
            alg = "ggadmm"
            [link]
            model = "latency:0.002,1e-9"
            [output]
            dir = "runs/smoke"
            checkpoint_every = 25
            "#,
        )
        .unwrap();
        assert_eq!(m.alg, "ggadmm");
        assert_eq!(m.exec.link, Some(LinkKind::Latency { base_s: 0.002, per_bit_s: 1e-9 }));
        assert_eq!(m.output.dir.as_deref(), Some(std::path::Path::new("runs/smoke")));
        assert_eq!(m.output.checkpoint_every, 25);
    }

    #[test]
    fn churn_section_parses_and_round_trips() {
        let m = ExperimentManifest::from_toml(
            r#"
            [churn]
            schedule = "10:leave:3 40:join:3"
            staleness_bound = 4
            "#,
        )
        .unwrap();
        let schedule = m.exec.churn.as_ref().expect("schedule parsed");
        assert_eq!(schedule.label(), "10:leave:3 40:join:3");
        assert_eq!(m.exec.staleness_bound, Some(4));
        assert_round_trips(&m);
        // each key works without the other
        let m = ExperimentManifest::from_toml("[churn]\nstaleness_bound = 2").unwrap();
        assert!(m.exec.churn.is_none());
        assert_eq!(m.exec.staleness_bound, Some(2));
        assert_round_trips(&m);
    }

    #[test]
    fn rejects_bad_churn_section() {
        assert!(ExperimentManifest::from_toml("[churn]\nschedule = \"10:evaporate:3\"")
            .unwrap_err()
            .contains("kind must be leave|join"));
        // staleness_bound = 0 is rejected by ExecutionConfig::validate
        assert!(ExperimentManifest::from_toml("[churn]\nstaleness_bound = 0")
            .unwrap_err()
            .contains("staleness_bound"));
    }

    #[test]
    fn rejects_unknown_alg_and_bad_link() {
        assert!(ExperimentManifest::from_toml("alg = \"sgd\"")
            .unwrap_err()
            .contains("unknown algorithm"));
        assert!(ExperimentManifest::from_toml("[link]\nmodel = \"carrier-pigeon\"")
            .unwrap_err()
            .contains("unknown link spec"));
    }
}
