//! The **one** execution-configuration surface: both engines
//! ([`crate::algs::Run`] and the sharded coordinator) and the sweep
//! scheduler consume the same [`ExecutionConfig`] value, so the
//! execution knobs cannot drift apart per engine again (the seed repo
//! grew three near-identical structs — `RunOptions`,
//! `CoordinatorOptions`, `ExecOptions` — which this replaces; those
//! names survive as thin legacy shims that convert `Into` this).
//!
//! `tests/coordinator_equivalence.rs` constructs both engines from one
//! shared value, which is what keeps the surfaces unified by force.

use crate::comm::{EnergyParams, LinkKind};
use crate::graph::ChurnSchedule;
use crate::solver::Backend;

/// Every knob of one run (engine-agnostic) plus the sweep scheduler's
/// run-level parallelism.  Construct with [`ExecutionConfig::default`]
/// and chain the `with_*` builders.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecutionConfig {
    pub backend: Backend,
    /// Artifact directory for the PJRT backend.
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Intra-run threads: group-parallel primal/dual updates (`1` =
    /// sequential, `0` = all cores).  In a sweep, only applied when the
    /// run can use the whole pool — concurrently scheduled runs execute
    /// single-threaded to avoid oversubscription.
    pub threads: usize,
    /// Concurrent runs across a sweep (run-level parallelism).  `1` =
    /// the serial driver; `0` = auto (all cores — unless `threads > 1`,
    /// in which case the explicit intra-run request wins and the sweep
    /// stays serial).  Any value reproduces the serial traces
    /// bit-for-bit: every run owns its spec-pinned seed and results are
    /// collected in job order.
    pub sweep_threads: usize,
    /// Seed for quantizer randomness and failure injection.
    pub seed: u64,
    /// Sample the trace every this many iterations (1 = every iteration).
    pub record_every: u64,
    /// Broadcast-erasure probability (failure injection): a transmission
    /// is lost with this probability — energy and bits are still spent,
    /// but receivers keep the stale value (erasure with perfect
    /// feedback).  Shorthand for `link = Some(LinkKind::Erasure { p })`.
    pub drop_prob: f64,
    /// Explicit link model; when `None`, `drop_prob` selects between
    /// [`LinkKind::Ideal`] and [`LinkKind::Erasure`].
    pub link: Option<LinkKind>,
    pub energy: EnergyParams,
    /// Censoring-aware incremental bookkeeping (default): neighbor sums
    /// and dual increments are rebuilt only when a hat in the worker's
    /// closed neighborhood committed.  `false` forces the from-scratch
    /// recompute every phase — bit-identical by construction.
    pub incremental: bool,
    /// Deterministic worker join/leave schedule (`None` = static graph;
    /// the legacy code path, bit-identical to before churn existed).
    pub churn: Option<ChurnSchedule>,
    /// Bounded-staleness round policy: rounds proceed without broadcasts
    /// that straggle past the slot, and a neighbor copy that has been
    /// stale for this many consecutive rounds is force-refreshed
    /// (censor gate bypassed, reliable delivery).  `None` = the legacy
    /// fully synchronous barrier.
    pub staleness_bound: Option<u64>,
}

impl Default for ExecutionConfig {
    fn default() -> Self {
        ExecutionConfig {
            backend: Backend::Native,
            artifacts_dir: None,
            threads: 1,
            sweep_threads: 1,
            seed: 7,
            record_every: 1,
            drop_prob: 0.0,
            link: None,
            energy: EnergyParams::default(),
            incremental: true,
            churn: None,
            staleness_bound: None,
        }
    }
}

impl ExecutionConfig {
    /// Saturate the machine: run-level parallelism across all cores.
    pub fn saturating() -> Self {
        ExecutionConfig {
            sweep_threads: crate::parallel::default_threads(),
            ..ExecutionConfig::default()
        }
    }

    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_artifacts_dir(mut self, dir: Option<std::path::PathBuf>) -> Self {
        self.artifacts_dir = dir;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_sweep_threads(mut self, sweep_threads: usize) -> Self {
        self.sweep_threads = sweep_threads;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_record_every(mut self, record_every: u64) -> Self {
        self.record_every = record_every;
        self
    }

    pub fn with_drop_prob(mut self, drop_prob: f64) -> Self {
        self.drop_prob = drop_prob;
        self
    }

    pub fn with_link(mut self, link: Option<LinkKind>) -> Self {
        self.link = link;
        self
    }

    pub fn with_energy(mut self, energy: EnergyParams) -> Self {
        self.energy = energy;
        self
    }

    pub fn with_incremental(mut self, incremental: bool) -> Self {
        self.incremental = incremental;
        self
    }

    pub fn with_churn(mut self, churn: Option<ChurnSchedule>) -> Self {
        self.churn = churn;
        self
    }

    pub fn with_staleness_bound(mut self, tau: Option<u64>) -> Self {
        self.staleness_bound = tau;
        self
    }

    /// Validate cross-field constraints shared by all consumers.
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.drop_prob) {
            return Err(format!("drop_prob {} out of [0,1]", self.drop_prob));
        }
        if self.record_every == 0 {
            return Err("record_every must be >= 1".into());
        }
        if self.backend == Backend::Pjrt && self.threads > 1 {
            return Err("the PJRT backend shares one client across workers; use threads = 1".into());
        }
        if self.backend == Backend::Pjrt && self.churn.as_ref().is_some_and(|c| !c.is_empty()) {
            return Err(
                "churn re-derives solver degrees, which the PJRT backend's staged \
                 device constants cannot do; use the native backend"
                    .into(),
            );
        }
        if self.staleness_bound == Some(0) {
            return Err("staleness_bound must be >= 1 (use none for the synchronous barrier)".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose() {
        let cfg = ExecutionConfig::default()
            .with_seed(42)
            .with_threads(4)
            .with_drop_prob(0.2)
            .with_record_every(5);
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.drop_prob, 0.2);
        assert_eq!(cfg.record_every, 5);
        // untouched knobs keep their defaults
        assert_eq!(cfg.sweep_threads, 1);
        assert!(cfg.incremental);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(ExecutionConfig::default().with_drop_prob(1.5).validate().is_err());
        assert!(ExecutionConfig::default().with_record_every(0).validate().is_err());
        let pjrt = ExecutionConfig::default()
            .with_backend(Backend::Pjrt)
            .with_threads(2);
        assert!(pjrt.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_dynamic_knobs() {
        assert!(ExecutionConfig::default()
            .with_staleness_bound(Some(0))
            .validate()
            .is_err());
        assert!(ExecutionConfig::default()
            .with_staleness_bound(Some(1))
            .validate()
            .is_ok());
        let churn = ChurnSchedule::parse("3:leave:1 6:join:1").unwrap();
        assert!(ExecutionConfig::default()
            .with_churn(Some(churn.clone()))
            .validate()
            .is_ok());
        assert!(ExecutionConfig::default()
            .with_backend(Backend::Pjrt)
            .with_churn(Some(churn))
            .validate()
            .is_err());
    }
}
