//! Experiment configuration: a TOML-subset parser plus typed configs.
//!
//! The sandbox has no `serde`/`toml`, so `parse_toml` implements the subset
//! the experiment files need: `[section]` headers, `key = value` with
//! string / float / integer / bool / flat-array values, and `#` comments.
//! Typed accessors with good error messages sit on top, and
//! [`ExperimentConfig`] is the validated struct the CLI and the experiment
//! harness consume.
//!
//! Higher layers: [`exec::ExecutionConfig`] is the one execution surface
//! both engines and the sweep scheduler consume, and
//! [`manifest::ExperimentManifest`] is the full layered TOML front end
//! (problem + algorithm + execution + link + output) every CLI
//! subcommand accepts via `--manifest`.

pub mod exec;
pub mod manifest;

pub use exec::ExecutionConfig;
pub use manifest::{ExperimentManifest, OutputConfig};

use std::collections::BTreeMap;

/// A scalar or flat-array TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: section -> key -> value.  Keys before any section
/// header live in section `""`.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .as_f64()
                .map(Some)
                .ok_or_else(|| format!("[{section}] {key}: expected number, got {v:?}")),
        }
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>, String> {
        match self.get_f64(section, key)? {
            None => Ok(None),
            Some(f) if f.fract() == 0.0 && f >= 0.0 => Ok(Some(f as usize)),
            Some(f) => Err(format!("[{section}] {key}: expected non-negative integer, got {f}")),
        }
    }

    pub fn get_str(&self, section: &str, key: &str) -> Result<Option<String>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .as_str()
                .map(|s| Some(s.to_string()))
                .ok_or_else(|| format!("[{section}] {key}: expected string, got {v:?}")),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .as_bool()
                .map(Some)
                .ok_or_else(|| format!("[{section}] {key}: expected bool, got {v:?}")),
        }
    }
}

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<Doc, String> {
    let mut doc = Doc::default();
    let mut section = String::new();
    doc.sections.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(body) = line.strip_prefix('[') {
            let name = body
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?;
            section = name.trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected 'key = value'", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(val.trim())
            .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
        doc.sections
            .get_mut(&section)
            .unwrap()
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        let trimmed = body.trim();
        if !trimmed.is_empty() {
            for item in split_top_level(trimmed) {
                items.push(parse_value(item.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(body.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    // arrays here are flat (no nesting), so a simple comma split outside
    // strings suffices
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

// ---------------------------------------------------------------------------
// Typed experiment configuration
// ---------------------------------------------------------------------------

/// A topology family + its parameters (the paper's "generalized" G):
/// every family is built deterministically from `(spec, n, seed)` by
/// [`crate::graph::gen::build`] and turned into a valid head/tail
/// instance by the bipartition pass.
///
/// CLI / TOML syntax (`TopologySpec::parse`):
/// `chain | ring | star | grid | torus | random[:p] | er[:p] |
/// smallworld[:k[,beta]] | geometric[:radius_m]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TopologySpec {
    /// Path 0-1-...-(n-1): the original GADMM topology.
    Chain,
    /// Cycle; bipartite iff `n` is even (odd rings drop one edge).
    Ring,
    /// Hub-and-spoke around worker 0; always bipartite.
    Star,
    /// Near-square 2D lattice; `torus` adds wraparound links.
    Grid { torus: bool },
    /// The paper's §7 generator: random balanced grouping + uniform
    /// head-tail edges at connectivity ratio `p`.
    RandomBipartite { p: f64 },
    /// Erdős–Rényi G(n, p) over a random spanning tree.
    ErdosRenyi { p: f64 },
    /// Watts–Strogatz: ring lattice of degree `k`, each link rewired
    /// with probability `beta`.
    SmallWorld { k: usize, beta: f64 },
    /// Random geometric graph: workers placed uniformly in the 500 m
    /// deployment square, linked within `radius_m` (energy-model
    /// distances are the real link lengths).
    Geometric { radius_m: f64 },
}

impl TopologySpec {
    /// Parse the `--topology` CLI / TOML syntax.  Omitted parameters get
    /// family defaults: `random:0.3`, `er:0.15`, `smallworld:4,0.1`,
    /// `geometric:200`.
    pub fn parse(s: &str) -> Result<TopologySpec, String> {
        let s = s.trim();
        let (family, params) = match s.split_once(':') {
            Some((f, p)) => (f.trim(), Some(p.trim())),
            None => (s, None),
        };
        let f64_param = |p: Option<&str>, default: f64, what: &str| -> Result<f64, String> {
            match p {
                None | Some("") => Ok(default),
                Some(v) => v
                    .parse::<f64>()
                    .map_err(|_| format!("topology '{family}': bad {what} '{v}'")),
            }
        };
        // parameterless families must reject a ':params' suffix — silently
        // ignoring it would run a different topology than requested
        let no_params = |spec: TopologySpec| -> Result<TopologySpec, String> {
            match params {
                Some(p) if !p.is_empty() => {
                    Err(format!("topology '{family}' takes no ':{p}' parameter"))
                }
                _ => Ok(spec),
            }
        };
        let spec = match family {
            "chain" => no_params(TopologySpec::Chain)?,
            "ring" => no_params(TopologySpec::Ring)?,
            "star" => no_params(TopologySpec::Star)?,
            "grid" => no_params(TopologySpec::Grid { torus: false })?,
            "torus" => no_params(TopologySpec::Grid { torus: true })?,
            "random" | "bipartite" => {
                TopologySpec::RandomBipartite { p: f64_param(params, 0.3, "connectivity p")? }
            }
            "er" | "erdos-renyi" => {
                TopologySpec::ErdosRenyi { p: f64_param(params, 0.15, "edge probability p")? }
            }
            "smallworld" => {
                let (k, beta) = match params {
                    None | Some("") => (4, 0.1),
                    Some(body) => match body.split_once(',') {
                        None => {
                            let k = body
                                .parse::<usize>()
                                .map_err(|_| format!("smallworld: bad degree k '{body}'"))?;
                            (k, 0.1)
                        }
                        Some((ks, bs)) => {
                            let k = ks
                                .trim()
                                .parse::<usize>()
                                .map_err(|_| format!("smallworld: bad degree k '{ks}'"))?;
                            let beta = bs
                                .trim()
                                .parse::<f64>()
                                .map_err(|_| format!("smallworld: bad beta '{bs}'"))?;
                            (k, beta)
                        }
                    },
                };
                TopologySpec::SmallWorld { k, beta }
            }
            "geometric" => {
                TopologySpec::Geometric { radius_m: f64_param(params, 200.0, "radius_m")? }
            }
            other => {
                return Err(format!(
                    "unknown topology '{other}' (expected chain|ring|star|grid|torus|\
                     random[:p]|er[:p]|smallworld[:k,beta]|geometric[:r])"
                ))
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Range-check the family parameters (n-independent; worker-count
    /// constraints are checked by the generator).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            TopologySpec::RandomBipartite { p } | TopologySpec::ErdosRenyi { p } => {
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("topology edge probability p={p} not in [0, 1]"));
                }
            }
            TopologySpec::SmallWorld { k, beta } => {
                if k < 2 {
                    return Err(format!("smallworld degree k={k} must be >= 2"));
                }
                if !(0.0..=1.0).contains(&beta) {
                    return Err(format!("smallworld beta={beta} not in [0, 1]"));
                }
            }
            TopologySpec::Geometric { radius_m } => {
                if !(radius_m > 0.0 && radius_m.is_finite()) {
                    return Err(format!("geometric radius_m={radius_m} must be finite and > 0"));
                }
            }
            TopologySpec::Chain
            | TopologySpec::Ring
            | TopologySpec::Star
            | TopologySpec::Grid { .. } => {}
        }
        Ok(())
    }

    /// Canonical label used in trace names and tables (round-trips
    /// through [`TopologySpec::parse`]).
    pub fn label(&self) -> String {
        match *self {
            TopologySpec::Chain => "chain".into(),
            TopologySpec::Ring => "ring".into(),
            TopologySpec::Star => "star".into(),
            TopologySpec::Grid { torus: false } => "grid".into(),
            TopologySpec::Grid { torus: true } => "torus".into(),
            TopologySpec::RandomBipartite { p } => format!("random:{p}"),
            TopologySpec::ErdosRenyi { p } => format!("er:{p}"),
            TopologySpec::SmallWorld { k, beta } => format!("smallworld:{k},{beta}"),
            TopologySpec::Geometric { radius_m } => format!("geometric:{radius_m}"),
        }
    }
}

impl std::fmt::Display for TopologySpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// Which regression task a run optimizes (paper §7.1/§7.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Linear,
    Logistic,
}

impl Task {
    pub fn parse(s: &str) -> Result<Task, String> {
        match s {
            "linear" => Ok(Task::Linear),
            "logistic" => Ok(Task::Logistic),
            _ => Err(format!("unknown task '{s}' (expected linear|logistic)")),
        }
    }
}

/// Which parameterization a worker optimizes: the paper's single-block
/// GLM (linear/logistic), or the one-hidden-layer MLP whose weights and
/// output layer form two parameter blocks (the L-FGADMM-style layer-wise
/// model; see [`crate::param::Blocks`]).
///
/// CLI / TOML syntax (`ModelSpec::parse`): `glm | mlp[:hidden]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelSpec {
    /// Single-block generalized linear model (the pre-refactor default).
    Glm,
    /// One hidden layer of `hidden` tanh units; blocks `[vec(W), v]`.
    Mlp { hidden: usize },
}

impl ModelSpec {
    /// Parse the `--model` CLI / TOML syntax (`mlp` defaults to 8 hidden
    /// units).
    pub fn parse(s: &str) -> Result<ModelSpec, String> {
        let s = s.trim();
        let (family, params) = match s.split_once(':') {
            Some((f, p)) => (f.trim(), Some(p.trim())),
            None => (s, None),
        };
        let spec = match family {
            "glm" => match params {
                Some(p) if !p.is_empty() => {
                    return Err(format!("model 'glm' takes no ':{p}' parameter"))
                }
                _ => ModelSpec::Glm,
            },
            "mlp" => {
                let hidden = match params {
                    None | Some("") => 8,
                    Some(v) => v
                        .parse::<usize>()
                        .map_err(|_| format!("model 'mlp': bad hidden-unit count '{v}'"))?,
                };
                ModelSpec::Mlp { hidden }
            }
            other => {
                return Err(format!("unknown model '{other}' (expected glm|mlp[:hidden])"))
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<(), String> {
        if let ModelSpec::Mlp { hidden } = *self {
            if hidden < 1 {
                return Err("mlp hidden-unit count must be >= 1".into());
            }
        }
        Ok(())
    }

    /// Canonical label (round-trips through [`ModelSpec::parse`]).
    pub fn label(&self) -> String {
        match *self {
            ModelSpec::Glm => "glm".into(),
            ModelSpec::Mlp { hidden } => format!("mlp:{hidden}"),
        }
    }
}

impl std::fmt::Display for ModelSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// Named dataset of Table 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetId {
    SynthLinear,
    BodyFat,
    SynthLogistic,
    Derm,
}

impl DatasetId {
    pub fn parse(s: &str) -> Result<DatasetId, String> {
        match s {
            "synth-linear" => Ok(DatasetId::SynthLinear),
            "bodyfat" => Ok(DatasetId::BodyFat),
            "synth-logistic" => Ok(DatasetId::SynthLogistic),
            "derm" => Ok(DatasetId::Derm),
            _ => Err(format!(
                "unknown dataset '{s}' (expected synth-linear|bodyfat|synth-logistic|derm)"
            )),
        }
    }

    pub fn task(&self) -> Task {
        match self {
            DatasetId::SynthLinear | DatasetId::BodyFat => Task::Linear,
            DatasetId::SynthLogistic | DatasetId::Derm => Task::Logistic,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetId::SynthLinear => "synth-linear",
            DatasetId::BodyFat => "bodyfat",
            DatasetId::SynthLogistic => "synth-logistic",
            DatasetId::Derm => "derm",
        }
    }
}

/// Fully validated experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub dataset: DatasetId,
    pub workers: usize,
    pub connectivity: f64,
    pub rho: f64,
    pub mu0: f64,
    pub iters: usize,
    pub seed: u64,
    /// censoring threshold tau0 (0 disables censoring)
    pub tau0: f64,
    /// censoring decay xi in (0,1)
    pub xi: f64,
    /// quantization step-size decay omega in (0,1)
    pub omega: f64,
    /// initial quantization bits
    pub bits0: u32,
    /// Per-layer bit allocation (`--bits0 24,8`): one initial width per
    /// parameter block.  `None` = uniform `bits0` on every block (the
    /// single-block legacy behavior).
    pub bits_split: Option<Vec<u32>>,
    pub threads: usize,
    /// Topology family; `None` keeps the legacy default (the paper's
    /// random-bipartite generator at `connectivity`, or a chain for the
    /// GADMM baseline).
    pub topology: Option<TopologySpec>,
    /// Model parameterization; `None` keeps the legacy single-block GLM.
    pub model: Option<ModelSpec>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: DatasetId::SynthLinear,
            workers: 24,
            connectivity: 0.3,
            rho: 1.0,
            mu0: 1e-2,
            iters: 300,
            seed: 1,
            tau0: 0.5,
            xi: 0.8,
            omega: 0.99,
            bits0: 2,
            bits_split: None,
            threads: 1,
            topology: None,
            model: None,
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML-subset file body (section `[experiment]` or root).
    pub fn from_toml(text: &str) -> Result<ExperimentConfig, String> {
        let doc = parse_toml(text)?;
        let sec = if doc.sections.contains_key("experiment") {
            "experiment"
        } else {
            ""
        };
        let mut cfg = ExperimentConfig::default();
        if let Some(s) = doc.get_str(sec, "dataset")? {
            cfg.dataset = DatasetId::parse(&s)?;
        }
        if let Some(v) = doc.get_usize(sec, "workers")? {
            cfg.workers = v;
        }
        if let Some(v) = doc.get_f64(sec, "connectivity")? {
            cfg.connectivity = v;
        }
        if let Some(v) = doc.get_f64(sec, "rho")? {
            cfg.rho = v;
        }
        if let Some(v) = doc.get_f64(sec, "mu0")? {
            cfg.mu0 = v;
        }
        if let Some(v) = doc.get_usize(sec, "iters")? {
            cfg.iters = v;
        }
        if let Some(v) = doc.get_f64(sec, "seed")? {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_f64(sec, "tau0")? {
            cfg.tau0 = v;
        }
        if let Some(v) = doc.get_f64(sec, "xi")? {
            cfg.xi = v;
        }
        if let Some(v) = doc.get_f64(sec, "omega")? {
            cfg.omega = v;
        }
        // `bits0` accepts a number (uniform width) or a string bits spec
        // ("24,8": one width per parameter block)
        match doc.get(sec, "bits0") {
            None => {}
            Some(Value::Num(_)) => {
                if let Some(v) = doc.get_usize(sec, "bits0")? {
                    cfg.bits0 = v as u32;
                }
            }
            Some(Value::Str(s)) => {
                let spec = crate::param::BitsSpec::parse(s)
                    .map_err(|e| format!("[{sec}] bits0: {e}"))?;
                cfg.bits0 = spec.per_block[0];
                cfg.bits_split =
                    if spec.is_uniform() { None } else { Some(spec.per_block.clone()) };
            }
            Some(v) => {
                return Err(format!(
                    "[{sec}] bits0: expected integer or bits-spec string, got {v:?}"
                ))
            }
        }
        if let Some(v) = doc.get_usize(sec, "threads")? {
            cfg.threads = v;
        }
        if let Some(s) = doc.get_str(sec, "topology")? {
            cfg.topology = Some(TopologySpec::parse(&s)?);
        }
        if let Some(s) = doc.get_str(sec, "model")? {
            cfg.model = Some(ModelSpec::parse(&s)?);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check parameter ranges (the convergence theory needs
    /// xi, omega in (0,1), rho > 0, ...).
    pub fn validate(&self) -> Result<(), String> {
        if self.workers < 2 {
            return Err("workers must be >= 2".into());
        }
        if !(0.0 < self.connectivity && self.connectivity <= 1.0) {
            return Err("connectivity must be in (0, 1]".into());
        }
        if self.rho <= 0.0 {
            return Err("rho must be > 0".into());
        }
        if self.tau0 < 0.0 {
            return Err("tau0 must be >= 0".into());
        }
        if !(0.0 < self.xi && self.xi < 1.0) {
            return Err("xi must be in (0, 1)".into());
        }
        if !(0.0 < self.omega && self.omega < 1.0) {
            return Err("omega must be in (0, 1)".into());
        }
        if self.bits0 < 1 || self.bits0 > 32 {
            // 32 is full precision: the wire codec packs 1..=32-bit codes
            return Err("bits0 must be in [1, 32]".into());
        }
        if let Some(split) = &self.bits_split {
            if split.is_empty() {
                return Err("bits_split must name at least one width".into());
            }
            if let Some(b) = split.iter().find(|b| !(1..=32).contains(*b)) {
                return Err(format!("bits_split width {b} out of range [1, 32]"));
            }
            if split[0] != self.bits0 {
                // the scalar is the first block's width; keeping them in
                // lockstep is what makes `to_toml` round-trip exactly
                return Err(format!(
                    "bits_split starts at {} but bits0 is {}",
                    split[0], self.bits0
                ));
            }
        }
        if let Some(m) = &self.model {
            m.validate()?;
        }
        if self.iters == 0 {
            return Err("iters must be > 0".into());
        }
        if let Some(t) = &self.topology {
            t.validate()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars_and_sections() {
        let doc = parse_toml(
            r#"
            # comment
            top = 1
            [experiment]
            dataset = "bodyfat"   # trailing comment
            workers = 18
            rho = 0.5
            censor = true
            arr = [1, 2.5, "x"]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_f64("", "top").unwrap(), Some(1.0));
        assert_eq!(
            doc.get_str("experiment", "dataset").unwrap(),
            Some("bodyfat".into())
        );
        assert_eq!(doc.get_usize("experiment", "workers").unwrap(), Some(18));
        assert_eq!(doc.get_bool("experiment", "censor").unwrap(), Some(true));
        match doc.get("experiment", "arr").unwrap() {
            Value::Arr(items) => {
                assert_eq!(items.len(), 3);
                assert_eq!(items[1], Value::Num(2.5));
                assert_eq!(items[2], Value::Str("x".into()));
            }
            v => panic!("expected array, got {v:?}"),
        }
    }

    #[test]
    fn errors_have_line_numbers() {
        let e = parse_toml("a = 1\nbroken line\n").unwrap_err();
        assert!(e.contains("line 2"), "{e}");
        let e = parse_toml("[oops\n").unwrap_err();
        assert!(e.contains("line 1"), "{e}");
        let e = parse_toml("k = [1, 2\n").unwrap_err();
        assert!(e.contains("unterminated array"), "{e}");
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = parse_toml(r##"k = "a#b""##).unwrap();
        assert_eq!(doc.get_str("", "k").unwrap(), Some("a#b".into()));
    }

    #[test]
    fn experiment_config_roundtrip() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [experiment]
            dataset = "derm"
            workers = 18
            connectivity = 0.4
            rho = 0.8
            iters = 500
            tau0 = 0.25
            xi = 0.9
            omega = 0.95
            bits0 = 3
            "#,
        )
        .unwrap();
        assert_eq!(cfg.dataset, DatasetId::Derm);
        assert_eq!(cfg.dataset.task(), Task::Logistic);
        assert_eq!(cfg.workers, 18);
        assert_eq!(cfg.bits0, 3);
    }

    #[test]
    fn validation_rejects_bad_ranges() {
        let mut cfg = ExperimentConfig::default();
        cfg.xi = 1.5;
        assert!(cfg.validate().is_err());
        cfg = ExperimentConfig::default();
        cfg.workers = 1;
        assert!(cfg.validate().is_err());
        cfg = ExperimentConfig::default();
        cfg.rho = 0.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn topology_spec_parse_all_families() {
        for (s, spec) in [
            ("chain", TopologySpec::Chain),
            ("ring", TopologySpec::Ring),
            ("star", TopologySpec::Star),
            ("grid", TopologySpec::Grid { torus: false }),
            ("torus", TopologySpec::Grid { torus: true }),
            ("random", TopologySpec::RandomBipartite { p: 0.3 }),
            ("random:0.4", TopologySpec::RandomBipartite { p: 0.4 }),
            ("er:0.2", TopologySpec::ErdosRenyi { p: 0.2 }),
            ("smallworld", TopologySpec::SmallWorld { k: 4, beta: 0.1 }),
            ("smallworld:6", TopologySpec::SmallWorld { k: 6, beta: 0.1 }),
            ("smallworld:6,0.25", TopologySpec::SmallWorld { k: 6, beta: 0.25 }),
            ("geometric:150", TopologySpec::Geometric { radius_m: 150.0 }),
        ] {
            assert_eq!(TopologySpec::parse(s).unwrap(), spec, "{s}");
        }
    }

    #[test]
    fn topology_spec_labels_roundtrip() {
        for s in [
            "chain",
            "ring",
            "star",
            "grid",
            "torus",
            "random:0.4",
            "er:0.2",
            "smallworld:6,0.25",
            "geometric:150",
        ] {
            let spec = TopologySpec::parse(s).unwrap();
            assert_eq!(TopologySpec::parse(&spec.label()).unwrap(), spec, "{s}");
        }
    }

    #[test]
    fn topology_spec_rejects_bad_input() {
        assert!(TopologySpec::parse("moebius").is_err());
        assert!(TopologySpec::parse("random:1.5").is_err());
        assert!(TopologySpec::parse("er:-0.1").is_err());
        assert!(TopologySpec::parse("smallworld:1").is_err());
        assert!(TopologySpec::parse("smallworld:4,2.0").is_err());
        assert!(TopologySpec::parse("geometric:0").is_err());
        assert!(TopologySpec::parse("geometric:abc").is_err());
        // parameterless families reject a params suffix instead of
        // silently running something else
        assert!(TopologySpec::parse("grid:4x8").is_err());
        assert!(TopologySpec::parse("torus:3").is_err());
        assert!(TopologySpec::parse("chain:1").is_err());
    }

    #[test]
    fn config_parses_topology_key() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [experiment]
            topology = "smallworld:6,0.2"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.topology, Some(TopologySpec::SmallWorld { k: 6, beta: 0.2 }));
        let err = ExperimentConfig::from_toml("topology = \"nope\"").unwrap_err();
        assert!(err.contains("unknown topology"), "{err}");
    }

    #[test]
    fn model_spec_parse_and_label_roundtrip() {
        assert_eq!(ModelSpec::parse("glm").unwrap(), ModelSpec::Glm);
        assert_eq!(ModelSpec::parse("mlp").unwrap(), ModelSpec::Mlp { hidden: 8 });
        assert_eq!(ModelSpec::parse("mlp:4").unwrap(), ModelSpec::Mlp { hidden: 4 });
        for s in ["glm", "mlp:8", "mlp:3"] {
            let spec = ModelSpec::parse(s).unwrap();
            assert_eq!(ModelSpec::parse(&spec.label()).unwrap(), spec, "{s}");
        }
        assert!(ModelSpec::parse("cnn").is_err());
        assert!(ModelSpec::parse("mlp:0").is_err());
        assert!(ModelSpec::parse("mlp:x").is_err());
        assert!(ModelSpec::parse("glm:3").is_err());
    }

    #[test]
    fn bits0_accepts_number_or_split_string() {
        let cfg = ExperimentConfig::from_toml("bits0 = 5").unwrap();
        assert_eq!(cfg.bits0, 5);
        assert_eq!(cfg.bits_split, None);
        let cfg = ExperimentConfig::from_toml("bits0 = \"24,8\"").unwrap();
        assert_eq!(cfg.bits0, 24);
        assert_eq!(cfg.bits_split, Some(vec![24, 8]));
        // a uniform string collapses to the legacy scalar
        let cfg = ExperimentConfig::from_toml("bits0 = \"7\"").unwrap();
        assert_eq!(cfg.bits0, 7);
        assert_eq!(cfg.bits_split, None);
        let err = ExperimentConfig::from_toml("bits0 = \"24,\"").unwrap_err();
        assert!(err.contains("grammar"), "{err}");
        let err = ExperimentConfig::from_toml("bits0 = \"33,8\"").unwrap_err();
        assert!(err.contains("range"), "{err}");
    }

    #[test]
    fn model_key_parses() {
        let cfg = ExperimentConfig::from_toml("model = \"mlp:6\"").unwrap();
        assert_eq!(cfg.model, Some(ModelSpec::Mlp { hidden: 6 }));
        assert!(ExperimentConfig::from_toml("model = \"lstm\"").is_err());
    }

    #[test]
    fn dataset_id_parse_all() {
        for (s, id) in [
            ("synth-linear", DatasetId::SynthLinear),
            ("bodyfat", DatasetId::BodyFat),
            ("synth-logistic", DatasetId::SynthLogistic),
            ("derm", DatasetId::Derm),
        ] {
            assert_eq!(DatasetId::parse(s).unwrap(), id);
        }
        assert!(DatasetId::parse("nope").is_err());
    }
}
