//! Run metrics: the loss-gap trace against every x-axis the paper plots
//! (iterations, cumulative communication rounds, bits, energy).

use crate::io::CsvWriter;
use std::path::Path;

/// One sampled point of a run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    pub iteration: u64,
    /// Objective error `|sum_n f_n(theta_n^k) - f*|`.
    pub loss_gap: f64,
    /// Consensus violation `max_(n,m) ||theta_n - theta_m||`.
    pub consensus_gap: f64,
    pub cum_rounds: u64,
    pub cum_bits: u64,
    pub cum_energy_j: f64,
}

/// Full trace of a run plus identity metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub algorithm: String,
    pub dataset: String,
    pub points: Vec<TracePoint>,
}

impl Trace {
    pub fn new(algorithm: &str, dataset: &str) -> Trace {
        Trace {
            algorithm: algorithm.to_string(),
            dataset: dataset.to_string(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    /// Final objective error.
    pub fn last_gap(&self) -> f64 {
        self.points.last().map(|p| p.loss_gap).unwrap_or(f64::INFINITY)
    }

    /// First point at which the loss gap drops below `target`; returns the
    /// x-coordinates the paper compares schemes at.
    pub fn first_below(&self, target: f64) -> Option<&TracePoint> {
        self.points.iter().find(|p| p.loss_gap <= target)
    }

    /// Gaps at or below this floor carry no rate information: an exactly
    /// converged tail (`gap == 0.0`) would feed `ln(0) = -inf` into the
    /// least-squares fit, and sub-1e-13 values are numerical noise.
    const RATE_FIT_GAP_FLOOR: f64 = 1e-13;

    /// Empirical linear-rate fit: least-squares slope of
    /// `log(gap_k)` over the window where the gap is decreasing and
    /// above numerical noise. Returns the per-iteration contraction factor
    /// `exp(slope)`.  Non-positive, sub-floor and non-finite gaps (exact
    /// convergence, diverged runs) are skipped so the fit never returns
    /// NaN; `None` when fewer than 4 informative points remain.
    pub fn fitted_rate(&self) -> Option<f64> {
        let pts: Vec<(f64, f64)> = self
            .points
            .iter()
            .filter(|p| p.loss_gap.is_finite() && p.loss_gap > Self::RATE_FIT_GAP_FLOOR)
            .map(|p| (p.iteration as f64, p.loss_gap.ln()))
            .collect();
        if pts.len() < 4 {
            return None;
        }
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|(x, _)| x).sum();
        let sy: f64 = pts.iter().map(|(_, y)| y).sum();
        let sxx: f64 = pts.iter().map(|(x, _)| x * x).sum();
        let sxy: f64 = pts.iter().map(|(x, y)| x * y).sum();
        let denom = n * sxx - sx * sx;
        if denom.abs() < 1e-12 {
            return None;
        }
        let slope = (n * sxy - sx * sy) / denom;
        Some(slope.exp())
    }

    /// Write the trace as CSV: one row per sampled iteration.
    pub fn to_csv(&self) -> CsvWriter {
        let mut w = CsvWriter::new(&[
            "algorithm",
            "dataset",
            "iteration",
            "loss_gap",
            "consensus_gap",
            "cum_rounds",
            "cum_bits",
            "cum_energy_j",
        ]);
        for p in &self.points {
            w.row(&[
                &self.algorithm,
                &self.dataset,
                &p.iteration.to_string(),
                &format!("{:.10e}", p.loss_gap),
                &format!("{:.10e}", p.consensus_gap),
                &p.cum_rounds.to_string(),
                &p.cum_bits.to_string(),
                &format!("{:.10e}", p.cum_energy_j),
            ]);
        }
        w
    }

    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        self.to_csv().save(path)
    }
}

/// Save several traces into one CSV (what the figure benches emit).
pub fn save_traces(traces: &[Trace], path: &Path) -> std::io::Result<()> {
    let mut w = CsvWriter::new(&[
        "algorithm",
        "dataset",
        "iteration",
        "loss_gap",
        "consensus_gap",
        "cum_rounds",
        "cum_bits",
        "cum_energy_j",
    ]);
    for t in traces {
        for p in &t.points {
            w.row(&[
                &t.algorithm,
                &t.dataset,
                &p.iteration.to_string(),
                &format!("{:.10e}", p.loss_gap),
                &format!("{:.10e}", p.consensus_gap),
                &p.cum_rounds.to_string(),
                &p.cum_bits.to_string(),
                &format!("{:.10e}", p.cum_energy_j),
            ]);
        }
    }
    w.save(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_trace(gaps: &[f64]) -> Trace {
        let mut t = Trace::new("test", "ds");
        for (i, &g) in gaps.iter().enumerate() {
            t.push(TracePoint {
                iteration: i as u64,
                loss_gap: g,
                consensus_gap: g / 10.0,
                cum_rounds: (i as u64) * 10,
                cum_bits: (i as u64) * 1000,
                cum_energy_j: i as f64 * 0.1,
            });
        }
        t
    }

    #[test]
    fn first_below_and_last_gap() {
        let t = mk_trace(&[1.0, 0.1, 0.01, 0.001]);
        assert_eq!(t.last_gap(), 0.001);
        let p = t.first_below(0.05).unwrap();
        assert_eq!(p.iteration, 2);
        assert!(t.first_below(1e-9).is_none());
    }

    #[test]
    fn fitted_rate_of_geometric_decay() {
        let gaps: Vec<f64> = (0..30).map(|k| 0.5f64.powi(k)).collect();
        let t = mk_trace(&gaps);
        let r = t.fitted_rate().unwrap();
        assert!((r - 0.5).abs() < 1e-6, "rate={r}");
    }

    #[test]
    fn fitted_rate_skips_exactly_converged_tail() {
        // a run that hits the optimum exactly: the zero-gap tail must be
        // skipped (ln(0) = -inf would poison the fit), leaving the clean
        // geometric prefix
        let gaps = [1.0, 0.5, 0.25, 0.125, 0.0625, 0.0, 0.0, 0.0, 0.0];
        let t = mk_trace(&gaps);
        let r = t.fitted_rate().expect("prefix has >= 4 informative points");
        assert!(r.is_finite(), "rate={r}");
        assert!((r - 0.5).abs() < 1e-6, "rate={r}");
    }

    #[test]
    fn fitted_rate_none_when_all_gaps_converged() {
        let t = mk_trace(&[0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert!(t.fitted_rate().is_none());
    }

    #[test]
    fn fitted_rate_skips_nonfinite_gaps() {
        // a diverged spike mid-trace must not leak inf/NaN into the fit
        let gaps = [1.0, f64::INFINITY, 0.5, f64::NAN, 0.25, 0.125, 0.0625];
        let t = mk_trace(&gaps);
        let r = t.fitted_rate().unwrap();
        assert!(r.is_finite());
    }

    #[test]
    fn csv_has_all_rows() {
        let t = mk_trace(&[1.0, 0.5]);
        let csv = t.to_csv();
        assert_eq!(csv.contents().lines().count(), 3);
        assert!(csv.contents().contains("loss_gap"));
    }
}
