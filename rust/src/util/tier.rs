//! Kernel-tier dispatch: one process-wide [`KernelTier`] selects between
//! the scalar-unrolled reference kernels and the explicitly vectorized
//! AVX2+FMA paths in `util` and `linalg::block`.
//!
//! Resolution happens once, on the first kernel call, with precedence
//!
//! 1. an explicit [`set_kernel_tier`] call (CLI `--kernel-tier`, tests),
//! 2. the `CQ_KERNEL_TIER` environment variable (`scalar` | `avx2` |
//!    `auto`),
//! 3. runtime CPU detection (`is_x86_feature_detected!("avx2")` + FMA).
//!
//! Requesting `avx2` on a machine without the features (or on a non-x86
//! target) degrades loudly to [`KernelTier::Scalar`] — the vectorized
//! entry points additionally re-check [`avx2_available`] before touching
//! an intrinsic, so a hand-constructed `KernelTier::Avx2` can never fault
//! on unsupported hardware.
//!
//! Determinism contract (see `linalg::block` for the kernel-level
//! details): results are deterministic and bit-stable **per tier**; the
//! AVX2 tier uses FMA inside `dot`/`norm2`-style reductions, so it agrees
//! with the scalar tier only to rounding (tolerance property tests), with
//! one deliberate exception — `util::axpy` avoids FMA and stays
//! bit-identical across tiers.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};

/// Which kernel implementation family the dense hot loops dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum KernelTier {
    /// The 4-wide unrolled scalar kernels: the bit-exact reference and
    /// the fallback on every non-AVX2 machine.
    Scalar = 1,
    /// Explicit AVX2+FMA intrinsics (`core::arch::x86_64`), selected
    /// only when runtime detection confirms both features.
    Avx2 = 2,
}

impl KernelTier {
    /// Stable lower-case name (`scalar` / `avx2`) used by the CLI, the
    /// env var and bench labels.
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
        }
    }

    /// The vectorized tier when this machine supports it, `None`
    /// otherwise.  Differential tests use this instead of constructing
    /// [`KernelTier::Avx2`] directly so they skip (rather than fall back
    /// silently) on non-AVX2 hardware.
    pub fn vectorized() -> Option<KernelTier> {
        if avx2_available() {
            Some(KernelTier::Avx2)
        } else {
            None
        }
    }
}

impl std::fmt::Display for KernelTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// `true` when the running CPU reports both AVX2 and FMA.  `std`'s
/// feature detection caches internally, so this is an atomic load after
/// the first call.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// 0 = unresolved, otherwise a `KernelTier as u8` value.
static TIER: AtomicU8 = AtomicU8::new(0);
/// Warn at most once when an `avx2` request degrades to scalar.
static WARNED: AtomicBool = AtomicBool::new(false);

fn warn_unsupported(source: &str) {
    if !WARNED.swap(true, Ordering::Relaxed) {
        eprintln!(
            "warning: {source} requested kernel tier 'avx2' but this CPU \
             lacks AVX2+FMA; falling back to 'scalar'"
        );
    }
}

fn detect() -> KernelTier {
    if avx2_available() {
        KernelTier::Avx2
    } else {
        KernelTier::Scalar
    }
}

fn resolve_from_env() -> KernelTier {
    match std::env::var("CQ_KERNEL_TIER") {
        Ok(v) => match v.trim().to_ascii_lowercase().as_str() {
            "scalar" => KernelTier::Scalar,
            "avx2" => {
                if avx2_available() {
                    KernelTier::Avx2
                } else {
                    warn_unsupported("CQ_KERNEL_TIER");
                    KernelTier::Scalar
                }
            }
            "" | "auto" => detect(),
            other => {
                eprintln!(
                    "warning: unrecognized CQ_KERNEL_TIER={other:?} \
                     (expected scalar|avx2|auto); auto-detecting"
                );
                detect()
            }
        },
        Err(_) => detect(),
    }
}

/// The process-wide tier every implicit-tier kernel dispatches through.
/// Resolved once (see module docs for precedence) and cached.
pub fn kernel_tier() -> KernelTier {
    match TIER.load(Ordering::Relaxed) {
        1 => KernelTier::Scalar,
        2 => KernelTier::Avx2,
        _ => {
            let t = resolve_from_env();
            // benign race: concurrent first calls resolve identically
            TIER.store(t as u8, Ordering::Relaxed);
            t
        }
    }
}

/// Force the process-wide tier (CLI override, tier-pinned tests, bench
/// shootouts).  Returns the tier actually installed: an `Avx2` request
/// on a machine without the features degrades to `Scalar` with a
/// one-time warning.
pub fn set_kernel_tier(tier: KernelTier) -> KernelTier {
    let effective = match tier {
        KernelTier::Avx2 if !avx2_available() => {
            warn_unsupported("set_kernel_tier");
            KernelTier::Scalar
        }
        t => t,
    };
    TIER.store(effective as u8, Ordering::Relaxed);
    effective
}

/// Parse a CLI-style tier request (`scalar` | `avx2` | `auto`;
/// case-insensitive).  `Ok(None)` means `auto` (run detection); unknown
/// values are an error so flag typos fail fast instead of silently
/// benchmarking the wrong tier.
pub fn parse_tier(value: &str) -> Result<Option<KernelTier>, String> {
    match value.trim().to_ascii_lowercase().as_str() {
        "scalar" => Ok(Some(KernelTier::Scalar)),
        "avx2" => Ok(Some(KernelTier::Avx2)),
        "auto" => Ok(None),
        other => Err(format!(
            "invalid kernel tier {other:?}: expected scalar|avx2|auto"
        )),
    }
}

/// Parse and apply a CLI-style tier override.  `auto` re-runs detection
/// (discarding any earlier pin and the env var).
pub fn apply_tier_override(value: &str) -> Result<KernelTier, String> {
    match parse_tier(value)? {
        Some(t) => Ok(set_kernel_tier(t)),
        None => {
            let t = detect();
            TIER.store(t as u8, Ordering::Relaxed);
            Ok(t)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        assert_eq!(KernelTier::Scalar.name(), "scalar");
        assert_eq!(KernelTier::Avx2.name(), "avx2");
        assert_eq!(format!("{}", KernelTier::Scalar), "scalar");
    }

    #[test]
    fn vectorized_matches_availability() {
        match KernelTier::vectorized() {
            Some(t) => {
                assert!(avx2_available());
                assert_eq!(t, KernelTier::Avx2);
            }
            None => assert!(!avx2_available()),
        }
    }

    #[test]
    fn parse_tier_accepts_and_rejects() {
        // apply_tier_override mutates process-global state that every
        // implicit-tier unit test in this binary reads, so only the pure
        // parser is exercised here (application is covered by the CLI
        // and the tier-pinned integration tests).
        assert_eq!(parse_tier("scalar"), Ok(Some(KernelTier::Scalar)));
        assert_eq!(parse_tier("AVX2"), Ok(Some(KernelTier::Avx2)));
        assert_eq!(parse_tier(" auto "), Ok(None));
        assert!(parse_tier("bogus").is_err());
    }
}
