//! Small shared utilities: deterministic RNG, float helpers and the
//! unrolled scalar kernels (`dot` / `norm2` / `axpy`) under every solver
//! hot loop.
//!
//! The reductions use four independent accumulators: that breaks the
//! additive dependency chain so the loop pipelines/vectorizes, at the
//! cost of reassociating the sum — `dot`/`norm2` therefore differ from a
//! naive left fold at the last-ulp level (bounded by tolerance property
//! tests below).  `axpy` performs exactly the per-element operation of
//! the naive loop, so it stays bit-identical (locked by an exact
//! property test).

pub mod rng;

/// Relative closeness check used across tests and differential checks.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Max absolute difference between two slices (panics on length mismatch).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Euclidean norm of a slice (4-wide unrolled reduction).
pub fn norm2(v: &[f64]) -> f64 {
    let chunks = v.chunks_exact(4);
    let rem = chunks.remainder();
    let mut acc = [0.0f64; 4];
    for c in chunks {
        acc[0] += c[0] * c[0];
        acc[1] += c[1] * c[1];
        acc[2] += c[2] * c[2];
        acc[3] += c[3] * c[3];
    }
    let mut tail = 0.0;
    for x in rem {
        tail += x * x;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3]) + tail).sqrt()
}

/// Dot product of two slices (4-wide unrolled reduction; panics on
/// length mismatch).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    let mut acc = [0.0f64; 4];
    for (x, y) in ca.zip(cb) {
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
    }
    let mut tail = 0.0;
    for (x, y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// `a += scale * b` in place (4-wide unrolled; bit-identical to the
/// naive loop — the per-element operation is unchanged).
pub fn axpy(a: &mut [f64], scale: f64, b: &[f64]) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    let mut ca = a.chunks_exact_mut(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        x[0] += scale * y[0];
        x[1] += scale * y[1];
        x[2] += scale * y[2];
        x[3] += scale * y[3];
    }
    for (x, y) in ca.into_remainder().iter_mut().zip(cb.remainder()) {
        *x += scale * y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    fn naive_dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    fn naive_norm2(v: &[f64]) -> f64 {
        v.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    fn naive_axpy(a: &mut [f64], scale: f64, b: &[f64]) {
        for (x, y) in a.iter_mut().zip(b) {
            *x += scale * y;
        }
    }

    #[test]
    fn close_basic() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(!close(1.0, 1.1, 1e-6, 0.0));
        assert!(close(0.0, 1e-12, 0.0, 1e-9));
    }

    #[test]
    fn norm_dot_axpy() {
        let a = vec![3.0, 4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-12);
        assert!((dot(&a, &a) - 25.0).abs() < 1e-12);
        let mut b = vec![1.0, 1.0];
        axpy(&mut b, 2.0, &a);
        assert_eq!(b, vec![7.0, 9.0]);
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
    }

    #[test]
    fn dot_matches_naive_within_reassociation() {
        // the unrolled reduction reassociates: bound the drift by the
        // condition of the sum, every length (remainder paths included)
        check("unrolled dot ~ naive dot", 200, |g| {
            let n = g.usize_in(0, 67);
            let a = g.normal_vec(n);
            let b = g.normal_vec(n);
            let fast = dot(&a, &b);
            let slow = naive_dot(&a, &b);
            let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
            assert!(
                (fast - slow).abs() <= 1e-12 * (1.0 + scale),
                "n={n}: {fast} vs {slow}"
            );
        });
    }

    #[test]
    fn norm2_matches_naive_within_reassociation() {
        check("unrolled norm2 ~ naive norm2", 200, |g| {
            let n = g.usize_in(0, 67);
            let v = g.normal_vec(n);
            let fast = norm2(&v);
            let slow = naive_norm2(&v);
            assert!(
                (fast - slow).abs() <= 1e-12 * (1.0 + slow),
                "n={n}: {fast} vs {slow}"
            );
        });
    }

    #[test]
    fn axpy_bit_identical_to_naive() {
        // the unroll does not change the per-element arithmetic: exact
        check("unrolled axpy == naive axpy (bitwise)", 200, |g| {
            let n = g.usize_in(0, 67);
            let base = g.normal_vec(n);
            let b = g.normal_vec(n);
            let s = g.f64_in(-3.0, 3.0);
            let mut fast = base.clone();
            axpy(&mut fast, s, &b);
            let mut slow = base;
            naive_axpy(&mut slow, s, &b);
            for (j, (x, y)) in fast.iter().zip(&slow).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "n={n} j={j}: {x:?} vs {y:?}"
                );
            }
        });
    }

    #[test]
    fn empty_and_short_slices() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(norm2(&[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        let mut a = [1.0];
        axpy(&mut a, 2.0, &[5.0]);
        assert_eq!(a, [11.0]);
    }
}
