//! Small shared utilities: deterministic RNG, float helpers.

pub mod rng;

/// Relative closeness check used across tests and differential checks.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Max absolute difference between two slices (panics on length mismatch).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Euclidean norm of a slice.
pub fn norm2(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Dot product of two slices (panics on length mismatch).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `a += scale * b` in place.
pub fn axpy(a: &mut [f64], scale: f64, b: &[f64]) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    for (x, y) in a.iter_mut().zip(b) {
        *x += scale * y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_basic() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(!close(1.0, 1.1, 1e-6, 0.0));
        assert!(close(0.0, 1e-12, 0.0, 1e-9));
    }

    #[test]
    fn norm_dot_axpy() {
        let a = vec![3.0, 4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-12);
        assert!((dot(&a, &a) - 25.0).abs() < 1e-12);
        let mut b = vec![1.0, 1.0];
        axpy(&mut b, 2.0, &a);
        assert_eq!(b, vec![7.0, 9.0]);
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
    }
}
