//! Small shared utilities: deterministic RNG, float helpers and the
//! tiered kernels (`dot` / `norm2` / `axpy`) under every solver hot
//! loop.
//!
//! Each kernel exists in two tiers (see [`tier`]): the 4-wide unrolled
//! **scalar** reference (`*_scalar`, kept verbatim as the bit-exact
//! baseline and the fallback on non-AVX2 machines) and an explicit
//! **AVX2+FMA** path dispatched at runtime through [`kernel_tier`].
//! `*_with_tier` variants take the tier explicitly so differential tests
//! can compare both without touching process-global state.
//!
//! Determinism: every tier is deterministic and bit-stable run-to-run.
//! The scalar reductions use four independent accumulators (breaking the
//! additive dependency chain so the loop pipelines), and the AVX2
//! reductions use two 4-lane FMA chains — both reassociate relative to a
//! naive left fold, and FMA removes one rounding per multiply-add, so
//! `dot`/`norm2` agree across tiers only to rounding (bounded by
//! tolerance property tests below).  `axpy` is the deliberate exception:
//! its AVX2 path uses multiply-then-add (no FMA), so the per-element
//! operation matches the naive loop exactly and `axpy` stays
//! **bit-identical across tiers** (locked by an exact property test) —
//! `linalg`'s transpose-matvec and triangular back-solves lean on that.

pub mod rng;
pub mod tier;

pub use tier::{avx2_available, kernel_tier, set_kernel_tier, KernelTier};

/// Relative closeness check used across tests and differential checks.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Max absolute difference between two slices (panics on length mismatch).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Euclidean norm of a slice (tier-dispatched).
pub fn norm2(v: &[f64]) -> f64 {
    norm2_with_tier(kernel_tier(), v)
}

/// [`norm2`] under an explicit tier.
pub fn norm2_with_tier(t: KernelTier, v: &[f64]) -> f64 {
    match t {
        KernelTier::Scalar => norm2_scalar(v),
        KernelTier::Avx2 => norm2_vectorized(v),
    }
}

/// Scalar reference norm (4-wide unrolled reduction).
pub fn norm2_scalar(v: &[f64]) -> f64 {
    let chunks = v.chunks_exact(4);
    let rem = chunks.remainder();
    let mut acc = [0.0f64; 4];
    for c in chunks {
        acc[0] += c[0] * c[0];
        acc[1] += c[1] * c[1];
        acc[2] += c[2] * c[2];
        acc[3] += c[3] * c[3];
    }
    let mut tail = 0.0;
    for x in rem {
        tail += x * x;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3]) + tail).sqrt()
}

/// Dot product of two slices (tier-dispatched; panics on length
/// mismatch).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_with_tier(kernel_tier(), a, b)
}

/// [`dot`] under an explicit tier.
pub fn dot_with_tier(t: KernelTier, a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    match t {
        KernelTier::Scalar => dot_scalar(a, b),
        KernelTier::Avx2 => dot_vectorized(a, b),
    }
}

/// Scalar reference dot (4-wide unrolled reduction).
pub fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    let mut acc = [0.0f64; 4];
    for (x, y) in ca.zip(cb) {
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
    }
    let mut tail = 0.0;
    for (x, y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// `a += scale * b` in place (tier-dispatched; bit-identical to the
/// naive loop on **every** tier — the per-element operation is
/// `a[i] + (scale * b[i])` with both roundings on each tier).
pub fn axpy(a: &mut [f64], scale: f64, b: &[f64]) {
    axpy_with_tier(kernel_tier(), a, scale, b)
}

/// [`axpy`] under an explicit tier (all tiers produce identical bits).
pub fn axpy_with_tier(t: KernelTier, a: &mut [f64], scale: f64, b: &[f64]) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    match t {
        KernelTier::Scalar => axpy_scalar(a, scale, b),
        KernelTier::Avx2 => axpy_vectorized(a, scale, b),
    }
}

/// Scalar reference axpy (4-wide unrolled).
pub fn axpy_scalar(a: &mut [f64], scale: f64, b: &[f64]) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    let mut ca = a.chunks_exact_mut(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        x[0] += scale * y[0];
        x[1] += scale * y[1];
        x[2] += scale * y[2];
        x[3] += scale * y[3];
    }
    for (x, y) in ca.into_remainder().iter_mut().zip(cb.remainder()) {
        *x += scale * y;
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn dot_vectorized(a: &[f64], b: &[f64]) -> f64 {
    if tier::avx2_available() {
        // SAFETY: runtime detection confirmed AVX2+FMA on this CPU.
        unsafe { avx2::dot(a, b) }
    } else {
        dot_scalar(a, b)
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn norm2_vectorized(v: &[f64]) -> f64 {
    if tier::avx2_available() {
        // SAFETY: runtime detection confirmed AVX2+FMA on this CPU.
        unsafe { avx2::norm2(v) }
    } else {
        norm2_scalar(v)
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn axpy_vectorized(a: &mut [f64], scale: f64, b: &[f64]) {
    if tier::avx2_available() {
        // SAFETY: runtime detection confirmed AVX2+FMA on this CPU.
        unsafe { avx2::axpy(a, scale, b) }
    } else {
        axpy_scalar(a, scale, b)
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn dot_vectorized(a: &[f64], b: &[f64]) -> f64 {
    dot_scalar(a, b)
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn norm2_vectorized(v: &[f64]) -> f64 {
    norm2_scalar(v)
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn axpy_vectorized(a: &mut [f64], scale: f64, b: &[f64]) {
    axpy_scalar(a, scale, b)
}

/// AVX2+FMA vector kernels.  Lane layout (shared with
/// `linalg::block`'s vectorized micro-kernels, which must mirror it for
/// the per-tier `matvec == dot` bit-identity contract):
///
/// * reductions run two independent 4-lane FMA chains over 8-element
///   steps (`acc0` holds elements `8k + 0..4`, `acc1` elements
///   `8k + 4..8`),
/// * the chains combine as one 4-lane vector add, then the horizontal
///   sum `(l0 + l1) + (l2 + l3)`,
/// * the scalar tail (`< 8` trailing elements) folds left with separate
///   multiply and add (no FMA).
#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    use core::arch::x86_64::*;

    /// 8-wide FMA dot product.
    ///
    /// # Safety
    /// Requires AVX2+FMA (callers gate on `tier::avx2_available`);
    /// `a.len() == b.len()` must hold.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut i = 0usize;
        while i + 8 <= n {
            let x0 = _mm256_loadu_pd(pa.add(i));
            let y0 = _mm256_loadu_pd(pb.add(i));
            let x1 = _mm256_loadu_pd(pa.add(i + 4));
            let y1 = _mm256_loadu_pd(pb.add(i + 4));
            acc0 = _mm256_fmadd_pd(x0, y0, acc0);
            acc1 = _mm256_fmadd_pd(x1, y1, acc1);
            i += 8;
        }
        let mut l = [0.0f64; 4];
        _mm256_storeu_pd(l.as_mut_ptr(), _mm256_add_pd(acc0, acc1));
        let mut tail = 0.0;
        while i < n {
            tail += *pa.add(i) * *pb.add(i);
            i += 1;
        }
        (l[0] + l[1]) + (l[2] + l[3]) + tail
    }

    /// 8-wide FMA sum of squares, rooted.
    ///
    /// # Safety
    /// Requires AVX2+FMA (callers gate on `tier::avx2_available`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn norm2(v: &[f64]) -> f64 {
        let n = v.len();
        let p = v.as_ptr();
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut i = 0usize;
        while i + 8 <= n {
            let x0 = _mm256_loadu_pd(p.add(i));
            let x1 = _mm256_loadu_pd(p.add(i + 4));
            acc0 = _mm256_fmadd_pd(x0, x0, acc0);
            acc1 = _mm256_fmadd_pd(x1, x1, acc1);
            i += 8;
        }
        let mut l = [0.0f64; 4];
        _mm256_storeu_pd(l.as_mut_ptr(), _mm256_add_pd(acc0, acc1));
        let mut tail = 0.0;
        while i < n {
            let x = *p.add(i);
            tail += x * x;
            i += 1;
        }
        ((l[0] + l[1]) + (l[2] + l[3]) + tail).sqrt()
    }

    /// 4-wide axpy.  Deliberately multiply-then-add (NOT FMA): each
    /// element computes `a[i] + (scale * b[i])` with both roundings, so
    /// the result is bit-identical to the scalar tier and the naive
    /// loop.
    ///
    /// # Safety
    /// Requires AVX2 (callers gate on `tier::avx2_available`);
    /// `a.len() == b.len()` must hold.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(a: &mut [f64], scale: f64, b: &[f64]) {
        let n = a.len();
        let pa = a.as_mut_ptr();
        let pb = b.as_ptr();
        let s = _mm256_set1_pd(scale);
        let mut i = 0usize;
        while i + 4 <= n {
            let acc = _mm256_loadu_pd(pa.add(i));
            let prod = _mm256_mul_pd(s, _mm256_loadu_pd(pb.add(i)));
            _mm256_storeu_pd(pa.add(i), _mm256_add_pd(acc, prod));
            i += 4;
        }
        while i < n {
            *pa.add(i) += scale * *pb.add(i);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    fn naive_dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    fn naive_norm2(v: &[f64]) -> f64 {
        v.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    fn naive_axpy(a: &mut [f64], scale: f64, b: &[f64]) {
        for (x, y) in a.iter_mut().zip(b) {
            *x += scale * y;
        }
    }

    #[test]
    fn close_basic() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(!close(1.0, 1.1, 1e-6, 0.0));
        assert!(close(0.0, 1e-12, 0.0, 1e-9));
    }

    #[test]
    fn norm_dot_axpy() {
        let a = vec![3.0, 4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-12);
        assert!((dot(&a, &a) - 25.0).abs() < 1e-12);
        let mut b = vec![1.0, 1.0];
        axpy(&mut b, 2.0, &a);
        assert_eq!(b, vec![7.0, 9.0]);
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
    }

    #[test]
    fn dot_matches_naive_within_reassociation() {
        // both tiers reassociate (and AVX2 adds FMA): bound the drift by
        // the condition of the sum, every length (tail paths included)
        check("tiered dot ~ naive dot", 200, |g| {
            let n = g.usize_in(0, 67);
            let a = g.normal_vec(n);
            let b = g.normal_vec(n);
            let fast = dot(&a, &b);
            let slow = naive_dot(&a, &b);
            let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
            assert!(
                (fast - slow).abs() <= 1e-12 * (1.0 + scale),
                "n={n}: {fast} vs {slow}"
            );
        });
    }

    #[test]
    fn norm2_matches_naive_within_reassociation() {
        check("tiered norm2 ~ naive norm2", 200, |g| {
            let n = g.usize_in(0, 67);
            let v = g.normal_vec(n);
            let fast = norm2(&v);
            let slow = naive_norm2(&v);
            assert!(
                (fast - slow).abs() <= 1e-12 * (1.0 + slow),
                "n={n}: {fast} vs {slow}"
            );
        });
    }

    #[test]
    fn axpy_bit_identical_to_naive() {
        // no tier changes the per-element arithmetic: exact on both
        check("tiered axpy == naive axpy (bitwise)", 200, |g| {
            let n = g.usize_in(0, 67);
            let base = g.normal_vec(n);
            let b = g.normal_vec(n);
            let s = g.f64_in(-3.0, 3.0);
            let mut fast = base.clone();
            axpy(&mut fast, s, &b);
            let mut slow = base.clone();
            naive_axpy(&mut slow, s, &b);
            for (j, (x, y)) in fast.iter().zip(&slow).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "n={n} j={j}: {x:?} vs {y:?}"
                );
            }
            if let Some(vec_tier) = KernelTier::vectorized() {
                let mut v = base;
                axpy_with_tier(vec_tier, &mut v, s, &b);
                for (j, (x, y)) in v.iter().zip(&slow).enumerate() {
                    assert!(
                        x.to_bits() == y.to_bits(),
                        "avx2 n={n} j={j}: {x:?} vs {y:?}"
                    );
                }
            }
        });
    }

    #[test]
    fn avx2_dot_norm2_match_scalar_within_fma_drift() {
        // cross-tier agreement is tolerance-level (FMA drops one
        // rounding per multiply-add); skip silently on non-AVX2 hosts
        let Some(vec_tier) = KernelTier::vectorized() else {
            return;
        };
        check("avx2 dot/norm2 ~ scalar", 200, |g| {
            let n = g.usize_in(0, 131);
            let a = g.normal_vec(n);
            let b = g.normal_vec(n);
            let dv = dot_with_tier(vec_tier, &a, &b);
            let ds = dot_scalar(&a, &b);
            let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
            assert!(
                (dv - ds).abs() <= 1e-12 * (1.0 + scale),
                "dot n={n}: {dv} vs {ds}"
            );
            let nv = norm2_with_tier(vec_tier, &a);
            let ns = norm2_scalar(&a);
            assert!(
                (nv - ns).abs() <= 1e-12 * (1.0 + ns),
                "norm2 n={n}: {nv} vs {ns}"
            );
        });
    }

    #[test]
    fn explicit_tier_matches_implicit_dispatch() {
        let t = kernel_tier();
        let a = vec![1.5, -2.0, 0.25, 3.0, -1.0, 0.5, 2.0, -0.75, 1.0];
        let b = vec![0.5, 1.0, -2.0, 0.25, 3.0, -1.5, 0.125, 2.0, -1.0];
        assert_eq!(dot(&a, &b).to_bits(), dot_with_tier(t, &a, &b).to_bits());
        assert_eq!(norm2(&a).to_bits(), norm2_with_tier(t, &a).to_bits());
    }

    #[test]
    fn empty_and_short_slices() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(norm2(&[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        let mut a = [1.0];
        axpy(&mut a, 2.0, &[5.0]);
        assert_eq!(a, [11.0]);
    }
}
