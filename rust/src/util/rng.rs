//! Deterministic PCG64 random number generator and distributions.
//!
//! The sandbox has no `rand` crate, and determinism across the whole stack
//! (data generation, topology sampling, stochastic quantization) is a
//! feature: every experiment in EXPERIMENTS.md is reproducible from its
//! seed. This is the PCG-XSL-RR 128/64 variant (O'Neill 2014).

/// PCG64 (XSL-RR 128/64) pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed the generator; `stream` selects one of 2^127 independent streams.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 {
            state: 0,
            inc,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed with the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child generator (used to give each worker its
    /// own stream so thread scheduling cannot perturb results).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64();
        Pcg64::with_stream(seed, tag.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire rejection (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; no caching so
    /// forked streams stay aligned).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli draw.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in `[0,1)`.
    pub fn uniform_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.uniform()).collect()
    }

    /// Raw `(state, inc)` pair for checkpointing. Restoring via
    /// [`Pcg64::from_raw`] resumes the stream at exactly this position.
    pub fn to_raw(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a raw `(state, inc)` pair.
    pub fn from_raw(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Pcg64::new(1);
        let n = 20_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(2);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m1 += x;
            m2 += x * x;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.03, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var={m2}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Pcg64::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(5);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
    }

    #[test]
    fn raw_round_trip_resumes_stream() {
        let mut a = Pcg64::new(9);
        for _ in 0..17 {
            a.next_u64();
        }
        let (state, inc) = a.to_raw();
        let mut b = Pcg64::from_raw(state, inc);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Pcg64::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
