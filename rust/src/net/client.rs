//! The worker side of the TCP transport: one process hosts one or more
//! worker ids, each driving its own [`WorkerCore`] over its own framed
//! connection.
//!
//! A session is a pure frame-driven state machine — the server decides
//! *when* anything happens (phases, commits, deliveries, churn, record
//! and checkpoint reads); the worker only runs the protocol arithmetic
//! locally and replies.  Per-connection TCP FIFO order is the only
//! synchronization: the server queues core mutations in the exact order
//! the in-process engines apply them, so replaying them here is
//! bit-identical.
//!
//! Construction is self-contained: the `Welcome` frame carries the
//! resolved manifest TOML, from which the worker rebuilds the problem,
//! topology and algorithm via [`super::build_session`] and its own core
//! via [`build_core_at`] — the same replayed RNG forks the in-process
//! fleet constructor uses.  The membership bitmap shapes the core for
//! mid-run structure (detached or degraded), and an optional `CoreState`
//! restores checkpointed or parked values.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::conn::Conn;
use super::wire::{self, kind};
use crate::algs::{AlgSpec, Problem};
use crate::config::ExperimentManifest;
use crate::coordinator::message;
use crate::graph::Topology;
use crate::io::checkpoint;
use crate::param::Blocks;
use crate::protocol::{build_core_at, PayloadRef, ProtocolConfig, WorkerCore};
use crate::solver::Backend;

/// Mirror of the server's barrier backstop: give up (with a clear
/// error) instead of spinning forever against a dead or wedged server.
const WAIT_TIMEOUT: Duration = Duration::from_secs(300);
const IDLE_BACKOFF: Duration = Duration::from_micros(100);

/// Options for one worker process.
pub struct WorkerOptions {
    /// Server address, e.g. `127.0.0.1:4800`.
    pub connect: String,
    /// Worker ids hosted by this process (each gets its own connection).
    pub ids: Vec<usize>,
    /// Exit cleanly (goodbye + state handoff) after completing this
    /// iteration — the socket analogue of a scheduled `leave`.
    pub exit_after_iter: Option<u64>,
}

/// Parse `--ids`: a single id (`"7"`) or a half-open range (`"0..16"`).
pub fn parse_ids(s: &str) -> Result<Vec<usize>, String> {
    let bad = |_| format!("--ids: cannot parse '{s}' (expected e.g. '7' or '0..16')");
    if let Some((a, b)) = s.split_once("..") {
        let a: usize = a.trim().parse().map_err(bad)?;
        let b: usize = b.trim().parse().map_err(bad)?;
        if a >= b {
            return Err(format!("--ids: empty range '{s}'"));
        }
        Ok((a..b).collect())
    } else {
        Ok(vec![s.trim().parse().map_err(bad)?])
    }
}

/// Run the worker process: register every id, then serve frames until
/// the server shuts the run down (or `exit_after_iter` departs cleanly).
pub fn run_worker(opts: &WorkerOptions) -> Result<(), String> {
    assert!(!opts.ids.is_empty(), "worker needs at least one id");
    // connect + hello for every hosted id
    let mut conns: Vec<(usize, Conn)> = Vec::with_capacity(opts.ids.len());
    for &id in &opts.ids {
        let stream = TcpStream::connect(&opts.connect)
            .map_err(|e| format!("cannot connect to {}: {e}", opts.connect))?;
        let mut c = Conn::new(stream).map_err(|e| format!("socket setup: {e}"))?;
        let h = c.begin(kind::HELLO);
        wire::put_u64(c.payload(), id as u64);
        c.end(h);
        conns.push((id, c));
    }
    // handshake: the first welcome's manifest builds the shared session;
    // every id then constructs its own core from it
    let mut ctx: Option<SessionContext> = None;
    let mut sessions: Vec<Session> = Vec::with_capacity(conns.len());
    for (id, mut conn) in conns {
        let body = await_frame(&mut conn, "welcome")?;
        let mut s = welcome_session(id, conn, &body, &mut ctx)
            .map_err(|e| format!("worker {id}: {e}"))?;
        s.exit_after = opts.exit_after_iter;
        sessions.push(s);
    }
    // main loop: serve frames on every session until all are done
    let mut deadline = Instant::now() + WAIT_TIMEOUT;
    loop {
        let mut progress = false;
        let mut all_done = true;
        for s in &mut sessions {
            progress |= s.pump()?;
            if !s.done || s.linger || s.conn.has_pending_send() {
                all_done = false;
            }
        }
        if all_done {
            return Ok(());
        }
        if progress {
            deadline = Instant::now() + WAIT_TIMEOUT;
        } else {
            if Instant::now() > deadline {
                return Err("timed out waiting for server frames".into());
            }
            std::thread::sleep(IDLE_BACKOFF);
        }
    }
}

/// Everything the hosted ids share, built once from the first welcome.
struct SessionContext {
    problem: Problem,
    topo: Topology,
    spec: AlgSpec,
    cfg: ProtocolConfig,
}

struct Session {
    id: usize,
    conn: Conn,
    core: WorkerCore,
    /// The core's block layout, cloned once so delivery decode can
    /// address spans while the core's slot is mutably borrowed.
    layout: Blocks,
    /// Iteration most recently computed (`k_plus_1` of the last phase).
    last_k1: u64,
    exit_after: Option<u64>,
    /// Decode scratch for warm/hat vectors (capacity retained).
    vec_scratch: Vec<f64>,
    /// Dispatch copy of the frame body (capacity retained) — splits the
    /// receive-buffer borrow from the core/send-buffer mutations.
    frame_scratch: Vec<u8>,
    done: bool,
    /// Departed via goodbye: hold the socket open (discarding frames)
    /// until the server closes its end.  Closing first could turn the
    /// server's in-flight writes into an RST that destroys the goodbye
    /// bytes still queued in the server's receive buffer.
    linger: bool,
}

/// Parse one `Welcome` frame and build the session for `id`.
fn welcome_session(
    id: usize,
    conn: Conn,
    body: &[u8],
    ctx: &mut Option<SessionContext>,
) -> Result<Session, String> {
    let (&k, rest) = body.split_first().ok_or("empty frame")?;
    if k != kind::WELCOME {
        return Err(format!("expected welcome, got frame kind {k}"));
    }
    let mut r = wire::Reader::new(rest);
    let resume_iter = r.u64("resume iteration")?;
    let n = r.u64("worker count")? as usize;
    let mut active = Vec::with_capacity(n);
    for _ in 0..n {
        active.push(r.u8("membership bitmap")? != 0);
    }
    let state = if r.u8("state flag")? != 0 {
        let len = r.u64("state length")? as usize;
        let rest = r.rest();
        if rest.len() < len {
            return Err("welcome state truncated".into());
        }
        let cs = checkpoint::decode_core(&rest[..len])?;
        r = wire::Reader::new(&rest[len..]);
        Some(cs)
    } else {
        None
    };
    if ctx.is_none() {
        let toml = std::str::from_utf8(r.rest())
            .map_err(|_| "welcome manifest is not UTF-8".to_string())?;
        let manifest = ExperimentManifest::from_toml(toml)?;
        manifest.validate()?;
        if manifest.exec.backend != Backend::Native {
            return Err("networked workers run native solvers only".into());
        }
        let (problem, topo, spec) = super::build_session(&manifest)?;
        let cfg = ProtocolConfig {
            backend: Backend::Native,
            artifacts_dir: None,
            incremental: manifest.exec.incremental,
            seed: manifest.exec.seed,
        };
        *ctx = Some(SessionContext { problem, topo, spec, cfg });
    }
    let ctx = ctx.as_ref().expect("session context");
    if n != ctx.topo.n() {
        return Err(format!(
            "welcome bitmap has {n} workers, manifest topology has {}",
            ctx.topo.n()
        ));
    }
    if id >= n {
        return Err(format!("worker id {id} out of range for n = {n}"));
    }
    let mut core = build_core_at(&ctx.problem, &ctx.topo, &ctx.spec, &ctx.cfg, id);
    core.enable_code_collection();
    // shape the core to the server's membership view: a detached self
    // drops every edge, an attached self drops edges to absent peers
    // (`set_degree` is a pure function of the final degree, so the
    // shape — not the detach history — determines the solver state)
    for m in core.neighbors().to_vec() {
        if !active[id] || !active[m] {
            core.detach_neighbor(m);
        }
    }
    if let Some(cs) = &state {
        core.import_state(cs);
    }
    let layout = core.block_layout();
    Ok(Session {
        id,
        conn,
        core,
        layout,
        last_k1: resume_iter,
        exit_after: None,
        vec_scratch: vec![0.0; ctx.problem.d],
        frame_scratch: Vec::new(),
        done: false,
        linger: false,
    })
}

/// Block (with timeout) until one complete frame arrives; returns the
/// copied body.
fn await_frame(conn: &mut Conn, what: &str) -> Result<Vec<u8>, String> {
    let deadline = Instant::now() + WAIT_TIMEOUT;
    loop {
        conn.flush()?;
        conn.pump_recv()?;
        if let Some(r) = conn.frame_range()? {
            let body = conn.bytes(r.clone()).to_vec();
            conn.consume(&r);
            return Ok(body);
        }
        if conn.peer_closed() {
            return Err(format!("server closed the connection before {what}"));
        }
        if Instant::now() > deadline {
            return Err(format!("timed out waiting for {what}"));
        }
        std::thread::sleep(IDLE_BACKOFF);
    }
}

impl Session {
    /// Drain the socket, handle every complete frame, flush replies.
    /// Returns `true` when bytes moved in either direction.
    fn pump(&mut self) -> Result<bool, String> {
        let mut progress = false;
        if !self.done {
            progress |= self.conn.pump_recv().map_err(|e| self.err(&e))?;
            loop {
                let Some(r) = self.conn.frame_range().map_err(|e| self.err(&e))? else {
                    if self.conn.peer_closed() {
                        return Err(self.err("server closed the connection mid-run"));
                    }
                    break;
                };
                let mut body = std::mem::take(&mut self.frame_scratch);
                body.clear();
                body.extend_from_slice(self.conn.bytes(r.clone()));
                self.conn.consume(&r);
                let res = self.handle_frame(&body);
                self.frame_scratch = body;
                res.map_err(|e| self.err(&e))?;
                progress = true;
                if self.done {
                    break;
                }
            }
        } else if self.linger {
            progress |= self.conn.pump_recv().map_err(|e| self.err(&e))?;
            while let Some(r) = self.conn.frame_range().map_err(|e| self.err(&e))? {
                self.conn.consume(&r);
                progress = true;
            }
            if self.conn.peer_closed() {
                self.linger = false;
            }
        }
        if self.conn.has_pending_send() {
            progress |= self.conn.flush().map_err(|e| self.err(&e))?;
        }
        Ok(progress)
    }

    /// Dispatch one server frame against the core.
    fn handle_frame(&mut self, body: &[u8]) -> Result<(), String> {
        let (&k, rest) = body.split_first().ok_or("empty frame")?;
        let mut r = wire::Reader::new(rest);
        match k {
            kind::PHASE => {
                let k1 = r.u64("phase iteration")?;
                let force = r.u8("force flag")? != 0;
                self.last_k1 = k1;
                self.core.primal_update();
                let decision = self.core.prepare_broadcast_gated(k1, force);
                let h = self.conn.begin(kind::CANDIDATE);
                match decision {
                    Some(bits) => {
                        self.conn.payload().push(1);
                        wire::put_u64(self.conn.payload(), bits);
                        self.encode_pending();
                    }
                    None => self.conn.payload().push(0),
                }
                self.conn.end(h);
            }
            kind::COMMIT => self.core.commit_pending(),
            kind::ABORT => self.core.abort_pending(),
            kind::DELIVER => {
                let from = r.u64("sender id")? as usize;
                let payload = r.rest();
                if self.core.neighbors().binary_search(&from).is_err() {
                    return Err(format!("delivery from non-neighbor {from}"));
                }
                let layout = &self.layout;
                let mut ok = true;
                self.core.deliver_with(from, |slot| {
                    ok = if layout.count() > 1 {
                        message::decode_blocks_into_slot(payload, layout, slot)
                    } else {
                        message::decode_into_slot(payload, slot)
                    };
                });
                if !ok {
                    return Err(format!("malformed broadcast payload from worker {from}"));
                }
            }
            kind::DUAL => {
                if !self.core.neighbors().is_empty() {
                    self.core.dual_update();
                }
                if self.exit_after == Some(self.last_k1) {
                    self.leave_cleanly();
                }
            }
            kind::REPORT_REQ => {
                let h = self.conn.begin(kind::REPORT);
                wire::put_f64(self.conn.payload(), self.core.loss());
                wire::put_f64s(self.conn.payload(), self.core.theta());
                self.conn.end(h);
            }
            kind::EXPORT_REQ => {
                let bytes = checkpoint::encode_core(&self.core.export_state());
                let h = self.conn.begin(kind::EXPORT);
                self.conn.payload().extend_from_slice(&bytes);
                self.conn.end(h);
            }
            kind::DETACH => {
                let peer = r.u64("departed peer")? as usize;
                if self.core.neighbors().binary_search(&peer).is_err() {
                    return Err(format!("detach of non-neighbor {peer}"));
                }
                self.core.detach_neighbor(peer);
            }
            kind::DETACH_ALL => {
                for m in self.core.neighbors().to_vec() {
                    self.core.detach_neighbor(m);
                }
            }
            kind::ATTACH => {
                let peer = r.u64("joining peer")? as usize;
                r.f64s_into(&mut self.vec_scratch, "joining hat")?;
                self.core.attach_neighbor(peer, &self.vec_scratch);
            }
            kind::REJOIN => {
                r.f64s_into(&mut self.vec_scratch, "warm start")?;
                self.core.rejoin_with(&self.vec_scratch);
                let count = r.u64("peer count")?;
                for _ in 0..count {
                    let peer = r.u64("peer id")? as usize;
                    r.f64s_into(&mut self.vec_scratch, "peer hat")?;
                    self.core.attach_neighbor(peer, &self.vec_scratch);
                }
            }
            kind::SHUTDOWN => self.done = true,
            other => return Err(format!("unexpected frame kind {other}")),
        }
        Ok(())
    }

    /// Encode the pending candidate into the send buffer: flat cores
    /// keep the original single-tag frame byte-for-byte; multi-block
    /// cores frame each transmitting block separately
    /// ([`message::TAG_BLOCKS`]) so a censored block ships nothing —
    /// the wire twin of the sharded engine's `ShardWorker` encoder.
    fn encode_pending(&mut self) {
        let nb = self.core.block_count();
        if nb > 1 {
            let mask = self.core.broadcast_mask().expect("multi-block candidate has a mask");
            message::begin_blocks_into(nb, self.conn.payload());
            for b in 0..nb {
                if !mask[b] {
                    message::encode_absent_block_into(self.conn.payload());
                    continue;
                }
                let at = message::begin_block_into(self.conn.payload());
                match self.core.pending_block_payload(b) {
                    PayloadRef::Full(span) => {
                        message::encode_full_into(span, self.conn.payload())
                    }
                    PayloadRef::Quantized { radius, bits, codes } => {
                        message::encode_quantized_into(radius, bits, codes, self.conn.payload())
                    }
                }
                message::finish_block_into(self.conn.payload(), at);
            }
            return;
        }
        match self.core.pending_payload() {
            PayloadRef::Full(v) => message::encode_full_into(v, self.conn.payload()),
            PayloadRef::Quantized { radius, bits, codes } => {
                message::encode_quantized_into(radius, bits, codes, self.conn.payload())
            }
        }
    }

    /// Clean departure at the end of the current iteration: ship the
    /// loss plus the post-detach state — exactly the frozen shape a
    /// scheduled leave parks in-process.
    fn leave_cleanly(&mut self) {
        let loss = self.core.loss();
        for m in self.core.neighbors().to_vec() {
            self.core.detach_neighbor(m);
        }
        let bytes = checkpoint::encode_core(&self.core.export_state());
        let h = self.conn.begin(kind::GOODBYE);
        wire::put_f64(self.conn.payload(), loss);
        self.conn.payload().extend_from_slice(&bytes);
        self.conn.end(h);
        self.done = true;
        self.linger = true;
    }

    fn err(&self, e: &str) -> String {
        format!("worker {}: {e}", self.id)
    }
}
