//! The networked coordinator: the leader loop of
//! [`crate::coordinator::Coordinator`] run against remote workers over
//! TCP instead of an in-process shard pool.
//!
//! One thread, one nonblocking `TcpListener`, one [`Conn`] per worker —
//! a poll-style readiness loop pumps every connection, parses frames in
//! place and resumes partial writes, so N workers multiplex onto a
//! single I/O thread.  The server owns everything global: the
//! [`crate::comm::Medium`] (bit/energy accounting and the erasure
//! stream), the link RNG, churn membership, staleness bookkeeping, the
//! trace and the event log.  Workers own their
//! [`crate::protocol::WorkerCore`]s and ship candidates optimistically
//! (payload + transmit decision in one frame), so a phase costs one
//! round trip.
//!
//! Determinism: phases are resolved in ascending worker order against
//! the identical medium/RNG state as the in-process engines, and the
//! server keeps a **hat mirror** — its copy of every worker's last
//! committed reconstruction, updated by decoding the same wire bytes
//! every receiver decodes — which makes churn warm-starts and rejoin
//! payloads bit-identical to the in-process arithmetic.
//!
//! Failure model: a clean worker departure (`Goodbye`, carrying loss +
//! post-detach state) degrades the run exactly like a scheduled
//! `leave` at the next iteration boundary, and a reconnect rejoins like
//! a scheduled `join`; an abrupt kill degrades best-effort (the round
//! treats the worker as censored until the boundary) without the
//! bit-exactness guarantee.

use std::cell::RefCell;
use std::net::{SocketAddr, TcpListener};
use std::time::{Duration, Instant};

use super::conn::Conn;
use super::wire::{self, kind};
use crate::algs::{AlgSpec, Problem, Schedule};
use crate::comm::{EnergyModel, LinkKind, Medium, SlotOutcome};
use crate::config::ExecutionConfig;
use crate::coordinator::message;
use crate::graph::{ChurnKind, Topology};
use crate::io::checkpoint::{self, MediumState, RunState};
use crate::io::{EventRecorder, EventSink, PersistableEngine};
use crate::metrics::{Trace, TracePoint};
use crate::protocol::{build_core_at, link_rng, CoreState, ProtocolConfig};
use crate::solver::Backend;

/// Hard ceiling on any wait for remote progress — a wedged worker (or a
/// worker that was SIGSTOPped rather than killed) fails the run loudly
/// instead of hanging CI forever.
const BARRIER_TIMEOUT: Duration = Duration::from_secs(300);

/// Backoff while no connection made progress (the readiness loop spins
/// on nonblocking sockets; localhost latencies make longer sleeps the
/// dominant cost).
const IDLE_BACKOFF: Duration = Duration::from_micros(100);

/// The networked twin of [`crate::coordinator::Coordinator`] — same
/// engine surface (`step` / `record` cadence / event log /
/// [`PersistableEngine`]), transport-backed fleet.
pub struct NetCoordinator {
    inner: RefCell<NetServer>,
}

struct NetServer {
    topo: Topology,
    problem: Problem,
    spec: AlgSpec,
    opts: ExecutionConfig,
    manifest_toml: String,
    medium: Medium,
    trace: Trace,
    iter: u64,
    phase_groups: Vec<Vec<usize>>,
    live_groups: Vec<Vec<usize>>,
    active: Vec<bool>,
    stale: Vec<u64>,
    /// Per-block staleness ages, flattened `n × nblocks` (empty for
    /// single-block problems — `stale` alone drives the flat path).
    /// Invariant: `stale[i]` equals the max over worker `i`'s block ages.
    block_stale: Vec<u64>,
    force_scratch: Vec<bool>,
    /// The server's copy of every worker's last committed `hat_self` —
    /// decoded from the same wire bytes the receivers decode, so it is
    /// bit-identical to what every neighbor holds.  Feeds churn
    /// warm-start arithmetic and rejoin/attach payloads.
    mirror: Vec<Vec<f64>>,
    /// Frozen state of departed workers (from `Goodbye`, or a restored
    /// checkpoint until the worker re-registers).
    parked: Vec<Option<CoreState>>,
    /// Last known per-worker loss (reported each record; frozen at the
    /// parked value while a worker is away).
    losses: Vec<f64>,
    /// Last reported per-worker model (consensus-gap input).
    thetas: Vec<Vec<f64>>,
    recorder: Option<EventRecorder>,
    started: bool,
    /// Set during the shutdown drain: worker-side closes are then the
    /// expected end-of-run handshake, not disconnects worth recording.
    closing: bool,

    // transport
    listener: TcpListener,
    conns: Vec<Option<Conn>>,
    /// Accepted sockets that have not said `Hello` yet.
    lobby: Vec<Conn>,
    /// Frame-copy scratch (capacity retained across rounds).
    frame_scratch: Vec<u8>,
    /// Per-worker candidate payload bytes for the phase in flight.
    cand_buf: Vec<Vec<u8>>,
    /// Candidate metadata: `None` until the reply arrives, then
    /// `Some(None)` for censored / `Some(Some(bits))` for a transmit.
    cand: Vec<Option<Option<u64>>>,
    report_ready: Vec<bool>,
    exports: Vec<Option<CoreState>>,
    /// Disconnects awaiting the next iteration boundary (mapped onto
    /// the churn machinery there).
    pending_leave: Vec<usize>,
    /// Reconnects awaiting the next iteration boundary.
    pending_join: Vec<usize>,
}

impl NetCoordinator {
    /// Bind the coordinator on `addr` (e.g. `127.0.0.1:0` for an
    /// ephemeral port) and build the leader-side run state.  Workers
    /// register over TCP; [`NetCoordinator::wait_for_fleet`] gates the
    /// first iteration on all of them being present.
    pub fn bind(
        problem: Problem,
        topo: Topology,
        spec: AlgSpec,
        opts: ExecutionConfig,
        manifest_toml: String,
        addr: &str,
    ) -> std::io::Result<NetCoordinator> {
        spec.validate().expect("invalid AlgSpec");
        opts.validate().expect("invalid ExecutionConfig");
        assert_eq!(opts.backend, Backend::Native, "the networked coordinator is native-only");
        let n = topo.n();
        let cfg = ProtocolConfig {
            backend: Backend::Native,
            artifacts_dir: None,
            incremental: opts.incremental,
            seed: opts.seed,
        };
        // same stream discipline as `build_cores`: the link model gets
        // the root RNG advanced past the quantizer forks, so the
        // networked erasure stream cannot drift from the in-process one
        let rng = link_rng(&spec, &cfg, n);
        let energy = EnergyModel::new(opts.energy, n, spec.concurrent_fraction());
        let medium = Medium::new(
            energy,
            opts.energy.slot_s,
            LinkKind::resolve(opts.link, opts.drop_prob).build(rng, n),
        );
        let trace = Trace::new(&spec.name, &problem.dataset_name);
        if let Some(w) = opts.churn.as_ref().and_then(|c| c.max_worker()) {
            assert!(w < n, "churn schedule names worker {w}, but the topology has {n} workers");
        }
        let phase_groups = match spec.schedule {
            Schedule::Alternating => vec![topo.heads(), topo.tails()],
            Schedule::Jacobian => vec![(0..n).collect()],
        };
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let d = problem.d;
        let nblocks = problem.blocks.count();
        Ok(NetCoordinator {
            inner: RefCell::new(NetServer {
                live_groups: phase_groups.clone(),
                phase_groups,
                active: vec![true; n],
                stale: vec![0; n],
                block_stale: vec![0; if nblocks > 1 { n * nblocks } else { 0 }],
                force_scratch: vec![false; n],
                mirror: vec![vec![0.0; d]; n],
                parked: vec![None; n],
                losses: vec![0.0; n],
                thetas: vec![vec![0.0; d]; n],
                recorder: None,
                started: false,
                closing: false,
                listener,
                conns: (0..n).map(|_| None).collect(),
                lobby: Vec::new(),
                frame_scratch: Vec::new(),
                cand_buf: vec![Vec::new(); n],
                cand: vec![None; n],
                report_ready: vec![false; n],
                exports: vec![None; n],
                pending_leave: Vec::new(),
                pending_join: Vec::new(),
                topo,
                problem,
                spec,
                opts,
                manifest_toml,
                medium,
                trace,
                iter: 0,
            }),
        })
    }

    /// The bound address (read the ephemeral port back after `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.inner.borrow().listener.local_addr().expect("listener address")
    }

    /// Attach a fresh streaming event log (same shape as the in-process
    /// engines; transport adds `worker_connect` / `worker_disconnect`).
    pub fn start_event_log(&mut self, sink: Box<dyn EventSink>) {
        let s = self.inner.get_mut();
        let mut rec = EventRecorder::new(sink, s.topo.n());
        rec.rebase(s.iter);
        rec.run_start(
            &s.trace.algorithm,
            &s.problem.dataset_name,
            s.topo.n(),
            s.problem.d,
            s.opts.seed,
        );
        s.recorder = Some(rec);
    }

    /// Attach an event log continuing an earlier one (resume).
    pub fn resume_event_log(&mut self, sink: Box<dyn EventSink>) {
        let s = self.inner.get_mut();
        let mut rec = EventRecorder::new(sink, s.topo.n());
        rec.rebase(s.iter);
        s.recorder = Some(rec);
    }

    /// Block (pumping the readiness loop) until every worker id has
    /// registered, then mark the run started.
    pub fn wait_for_fleet(&mut self) {
        let s = self.inner.get_mut();
        s.pump_until("fleet registration", |s| s.conns.iter().all(|c| c.is_some()));
        s.started = true;
    }

    /// Execute one full iteration (the [`PersistableEngine`] step).
    pub fn step(&mut self) {
        self.inner.get_mut().step();
    }

    /// Run `iters` iterations, then return the trace accumulated so far.
    pub fn run(&mut self, iters: u64) -> Trace {
        for _ in 0..iters {
            self.step();
        }
        self.inner.borrow().trace.clone()
    }

    pub fn iteration(&self) -> u64 {
        self.inner.borrow().iter
    }

    pub fn trace(&self) -> Trace {
        self.inner.borrow().trace.clone()
    }

    /// Snapshot the durable run state — same layout as the in-process
    /// engines (`tests/net_equivalence.rs` compares the encoded bytes),
    /// assembled from live worker exports plus parked departed state.
    pub fn snapshot_state(&self) -> RunState {
        self.inner.borrow_mut().snapshot_state()
    }

    /// Restore from a checkpoint **before** the fleet registers: workers
    /// receive their `CoreState` (and the membership bitmap) in the
    /// `Welcome` frame when they connect.
    pub fn restore_state(&mut self, s: &RunState) {
        self.inner.get_mut().restore_state(s);
    }

    /// Send `Shutdown` to every connected worker and drain the sockets.
    pub fn shutdown(&mut self) {
        self.inner.get_mut().shutdown();
    }
}

impl PersistableEngine for NetCoordinator {
    fn step(&mut self) {
        NetCoordinator::step(self);
    }
    fn iteration(&self) -> u64 {
        NetCoordinator::iteration(self)
    }
    fn snapshot_state(&self) -> RunState {
        NetCoordinator::snapshot_state(self)
    }
    fn restore_state(&mut self, state: &RunState) {
        NetCoordinator::restore_state(self, state);
    }
    fn recorder_mut(&mut self) -> Option<&mut EventRecorder> {
        self.inner.get_mut().recorder.as_mut()
    }
}

impl NetServer {
    // ---- readiness loop ------------------------------------------------

    /// One pass over every socket: accept, read, parse + handle complete
    /// frames, resume partial writes.  Returns whether anything moved.
    fn pump_io(&mut self) -> bool {
        let mut progress = self.accept_new();
        progress |= self.pump_lobby();
        for i in 0..self.conns.len() {
            progress |= self.pump_worker(i);
        }
        self.flush_all();
        progress
    }

    /// Pump until `done` holds, with the barrier timeout as a backstop.
    fn pump_until(&mut self, what: &str, done: impl Fn(&NetServer) -> bool) {
        let deadline = Instant::now() + BARRIER_TIMEOUT;
        loop {
            let progress = self.pump_io();
            if done(self) {
                return;
            }
            if !progress {
                assert!(
                    Instant::now() < deadline,
                    "transport barrier timed out waiting for {what} at iteration {}",
                    self.iter
                );
                std::thread::sleep(IDLE_BACKOFF);
            }
        }
    }

    fn accept_new(&mut self) -> bool {
        let mut got = false;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => match Conn::new(stream) {
                    Ok(c) => {
                        self.lobby.push(c);
                        got = true;
                    }
                    Err(e) => eprintln!("rejecting connection: {e}"),
                },
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return got,
                Err(e) => panic!("listener accept failed: {e}"),
            }
        }
    }

    /// Pump unregistered sockets; the first frame must be `Hello`.
    fn pump_lobby(&mut self) -> bool {
        let mut progress = false;
        let mut k = 0;
        while k < self.lobby.len() {
            let mut drop_it = false;
            let mut hello: Option<usize> = None;
            {
                let c = &mut self.lobby[k];
                match c.pump_recv() {
                    Ok(g) => progress |= g,
                    Err(e) => {
                        eprintln!("lobby socket error: {e}");
                        drop_it = true;
                    }
                }
                if !drop_it {
                    match c.frame_range() {
                        Ok(Some(r)) => {
                            match parse_hello(c.bytes(r.clone())) {
                                Ok(id) => hello = Some(id),
                                Err(e) => {
                                    eprintln!("rejecting connection: {e}");
                                    drop_it = true;
                                }
                            }
                            c.consume(&r);
                        }
                        Ok(None) => drop_it = c.peer_closed(),
                        Err(e) => {
                            eprintln!("rejecting connection: {e}");
                            drop_it = true;
                        }
                    }
                }
            }
            if let Some(id) = hello {
                let c = self.lobby.swap_remove(k);
                self.register(id, c);
                progress = true;
            } else if drop_it {
                self.lobby.swap_remove(k);
                progress = true;
            } else {
                k += 1;
            }
        }
        progress
    }

    /// A worker said `Hello`: welcome it with the resume iteration, the
    /// membership bitmap, its parked state (if any) and the manifest.
    fn register(&mut self, id: usize, mut c: Conn) {
        let n = self.topo.n();
        if id >= n {
            eprintln!("rejecting hello: worker id {id} out of range for n = {n}");
            return;
        }
        if self.conns[id].is_some() {
            eprintln!("rejecting hello: worker {id} is already connected");
            return;
        }
        // A reconnect mid-run rejoins at the next boundary; its own
        // bitmap entry is forced inactive so it builds the detached
        // structure its parked state (if any) matches.
        let rejoining = self.started;
        let h = c.begin(kind::WELCOME);
        wire::put_u64(c.payload(), self.iter);
        wire::put_u64(c.payload(), n as u64);
        for (j, &on) in self.active.iter().enumerate() {
            let on = on && !(rejoining && j == id);
            c.payload().push(on as u8);
        }
        match &self.parked[id] {
            Some(state) => {
                c.payload().push(1);
                let bytes = checkpoint::encode_core(state);
                wire::put_u64(c.payload(), bytes.len() as u64);
                c.payload().extend_from_slice(&bytes);
            }
            None => c.payload().push(0),
        }
        c.payload().extend_from_slice(self.manifest_toml.as_bytes());
        c.end(h);
        self.conns[id] = Some(c);
        if rejoining {
            self.pending_join.push(id);
        }
        if let Some(rec) = &mut self.recorder {
            rec.worker_connect(self.iter, id);
        }
    }

    /// Read frames from worker `i`'s socket and dispatch them.
    fn pump_worker(&mut self, i: usize) -> bool {
        let Some(c) = self.conns[i].as_mut() else { return false };
        let mut progress = match c.pump_recv() {
            Ok(g) => g,
            Err(e) => {
                self.drop_worker(i, &format!("read failed: {e}"));
                return true;
            }
        };
        loop {
            let Some(c) = self.conns[i].as_mut() else { break };
            let range = match c.frame_range() {
                Ok(r) => r,
                Err(e) => {
                    self.drop_worker(i, &format!("bad frame: {e}"));
                    return true;
                }
            };
            let Some(range) = range else {
                if c.peer_closed() {
                    self.drop_worker(i, "peer closed without goodbye");
                    return true;
                }
                break;
            };
            let mut scratch = std::mem::take(&mut self.frame_scratch);
            scratch.clear();
            {
                let c = self.conns[i].as_mut().expect("conn");
                scratch.extend_from_slice(c.bytes(range.clone()));
                c.consume(&range);
            }
            let res = self.handle_worker_frame(i, &scratch);
            self.frame_scratch = scratch;
            if let Err(e) = res {
                self.drop_worker(i, &e);
                return true;
            }
            progress = true;
        }
        progress
    }

    fn handle_worker_frame(&mut self, i: usize, body: &[u8]) -> Result<(), String> {
        let (&k, rest) = body.split_first().ok_or("empty frame")?;
        let mut r = wire::Reader::new(rest);
        match k {
            kind::CANDIDATE => {
                let transmit = r.u8("transmit flag")? != 0;
                if transmit {
                    let bits = r.u64("payload bits")?;
                    self.cand_buf[i].clear();
                    self.cand_buf[i].extend_from_slice(r.rest());
                    self.cand[i] = Some(Some(bits));
                } else {
                    self.cand[i] = Some(None);
                }
            }
            kind::REPORT => {
                self.losses[i] = r.f64("reported loss")?;
                r.f64s_into(&mut self.thetas[i], "reported theta")?;
                self.report_ready[i] = true;
            }
            kind::EXPORT => {
                self.exports[i] = Some(checkpoint::decode_core(r.rest())?);
            }
            kind::GOODBYE => {
                let loss = r.f64("goodbye loss")?;
                let state = checkpoint::decode_core(r.rest())?;
                self.losses[i] = loss;
                self.thetas[i].copy_from_slice(&state.theta);
                self.parked[i] = Some(state);
                self.conns[i] = None;
                if self.active[i] && !self.pending_leave.contains(&i) {
                    self.pending_leave.push(i);
                }
                if let Some(rec) = &mut self.recorder {
                    rec.worker_disconnect(self.iter, i);
                }
            }
            kind::HELLO => return Err("unexpected hello on a registered connection".into()),
            other => return Err(format!("unexpected frame kind {other} from worker {i}")),
        }
        Ok(())
    }

    /// Tear down worker `i`'s connection (abrupt path: no parked state).
    /// The run degrades at the next boundary like a scheduled leave.
    fn drop_worker(&mut self, i: usize, reason: &str) {
        if self.conns[i].take().is_none() || self.closing {
            return;
        }
        eprintln!("worker {i} disconnected: {reason}");
        if self.active[i] && !self.pending_leave.contains(&i) {
            self.pending_leave.push(i);
        }
        if let Some(rec) = &mut self.recorder {
            rec.worker_disconnect(self.iter, i);
        }
    }

    fn flush_all(&mut self) {
        for i in 0..self.conns.len() {
            self.flush_one(i);
        }
    }

    fn flush_one(&mut self, i: usize) {
        let err = {
            let Some(c) = self.conns[i].as_mut() else { return };
            c.flush().err()
        };
        if let Some(e) = err {
            self.drop_worker(i, &format!("flush failed: {e}"));
        }
    }

    // ---- engine --------------------------------------------------------

    /// Bottleneck broadcast distance over **active** neighbors (the
    /// in-process engines' twin fold).
    fn active_neighbor_distance(&self, i: usize) -> f64 {
        self.topo
            .neighbors(i)
            .iter()
            .filter(|&&m| self.active[m])
            .map(|&m| self.topo.distance(i, m))
            .fold(0.0, f64::max)
    }

    /// One phase over `group`: dispatch `Phase` frames (one batched
    /// write per connection), barrier on the candidate replies, then
    /// resolve the broadcasts in ascending worker order — identical
    /// bookkeeping to `Coordinator::run_phase`.
    fn run_phase(&mut self, group: &[usize], k_plus_1: u64) {
        let tau = self.opts.staleness_bound;
        let nb = self.problem.blocks.count();
        let multi = nb > 1;
        for &i in group {
            // multi-block: any single block past the bound forces a full
            // reliable refresh — same rule as the in-process engines
            self.force_scratch[i] = match tau {
                None => false,
                Some(t) if multi => {
                    self.block_stale[i * nb..(i + 1) * nb].iter().any(|&a| a >= t)
                }
                Some(t) => self.stale[i] >= t,
            };
        }
        debug_assert!(group.windows(2).all(|w| w[0] < w[1]), "group must be increasing");
        // 1. dispatch: every live member computes its primal + candidate
        // remotely and replies with the payload and transmit decision
        for &i in group {
            self.cand[i] = None;
            match self.conns[i].as_mut() {
                Some(c) => {
                    let h = c.begin(kind::PHASE);
                    wire::put_u64(c.payload(), k_plus_1);
                    c.payload().push(self.force_scratch[i] as u8);
                    c.end(h);
                }
                // vanished abruptly mid-iteration: the round sees it as
                // censored; the boundary will degrade it properly
                None => self.cand[i] = Some(None),
            }
        }
        self.flush_all();
        self.pump_until("phase candidates", |s| {
            group.iter().all(|&i| s.cand[i].is_some() || s.conns[i].is_none())
        });
        // 2. sequential resolution on the leader, ascending worker order
        for &i in group {
            if let Some(rec) = &mut self.recorder {
                rec.note_attempt();
            }
            let force = self.force_scratch[i];
            let Some(Some(bits)) = self.cand[i] else {
                if tau.is_some() {
                    self.stale[i] += 1;
                    if multi {
                        for a in &mut self.block_stale[i * nb..(i + 1) * nb] {
                            *a += 1;
                        }
                    }
                }
                continue;
            };
            // per-block ledger: the frame's sub-payload sizes reproduce
            // the worker's masked candidate bits exactly (absent blocks
            // count zero); like the medium's totals, the cost is paid
            // whether or not the broadcast lands
            let per_block = if multi {
                let per =
                    message::counted_bits_per_block(&self.cand_buf[i], &self.problem.blocks)
                        .unwrap_or_else(|| {
                            panic!("malformed candidate payload from worker {i}")
                        });
                debug_assert_eq!(per.iter().sum::<u64>(), bits);
                self.medium.record_block_bits(&per);
                Some(per)
            } else {
                None
            };
            let dist = self.active_neighbor_distance(i);
            let landed = match tau {
                None => self.medium.transmit(i, self.iter, bits, dist),
                Some(_) => matches!(
                    self.medium.transmit_bounded(i, self.iter, bits, dist, force),
                    SlotOutcome::Landed
                ),
            };
            if landed {
                let ok = if multi {
                    message::decode_blocks_into_slot(
                        &self.cand_buf[i],
                        &self.problem.blocks,
                        &mut self.mirror[i],
                    )
                } else {
                    message::decode_into_slot(&self.cand_buf[i], &mut self.mirror[i])
                };
                assert!(ok, "malformed candidate payload from worker {i}");
                if let Some(c) = self.conns[i].as_mut() {
                    c.push_frame(kind::COMMIT);
                }
                for &m in self.topo.neighbors(i) {
                    if !self.active[m] {
                        continue;
                    }
                    if let Some(c) = self.conns[m].as_mut() {
                        let h = c.begin(kind::DELIVER);
                        wire::put_u64(c.payload(), i as u64);
                        c.payload().extend_from_slice(&self.cand_buf[i]);
                        c.end(h);
                    }
                }
                if force {
                    let staleness = self.stale[i];
                    if let Some(rec) = &mut self.recorder {
                        rec.stale_refresh(self.iter, i, staleness);
                    }
                }
                if multi && tau.is_some() {
                    // committed blocks reset; still-censored blocks keep
                    // aging — `stale[i]` mirrors the worst block
                    let per = per_block.as_ref().expect("multi-block candidate bits");
                    let ages = &mut self.block_stale[i * nb..(i + 1) * nb];
                    for (a, &b) in ages.iter_mut().zip(per) {
                        if b > 0 {
                            *a = 0;
                        } else {
                            *a += 1;
                        }
                    }
                    self.stale[i] = ages.iter().copied().max().unwrap_or(0);
                } else {
                    self.stale[i] = 0;
                }
            } else {
                if let Some(c) = self.conns[i].as_mut() {
                    c.push_frame(kind::ABORT);
                }
                if tau.is_some() {
                    self.stale[i] += 1;
                    if multi {
                        for a in &mut self.block_stale[i * nb..(i + 1) * nb] {
                            *a += 1;
                        }
                    }
                }
            }
        }
        self.medium.end_slot();
    }

    fn refresh_live_groups(&mut self) {
        self.live_groups = self
            .phase_groups
            .iter()
            .map(|g| {
                g.iter()
                    .copied()
                    .filter(|&i| {
                        self.active[i]
                            && self.topo.neighbors(i).iter().any(|&m| self.active[m])
                    })
                    .collect()
            })
            .collect();
    }

    /// Scheduled leave (or the boundary half of a clean disconnect):
    /// detach the worker everywhere, both directions, ascending order —
    /// the wire version of `protocol::apply_churn_event`.
    fn leave(&mut self, w: usize) {
        assert!(self.active[w], "leave while absent");
        if let Some(c) = self.conns[w].as_mut() {
            c.push_frame(kind::DETACH_ALL);
        }
        for &m in self.topo.neighbors(w) {
            if !self.active[m] {
                continue;
            }
            if let Some(c) = self.conns[m].as_mut() {
                let h = c.begin(kind::DETACH);
                wire::put_u64(c.payload(), w as u64);
                c.end(h);
            }
        }
        self.active[w] = false;
    }

    /// Scheduled join (or the boundary half of a reconnect): warm-start
    /// from the mirror mean over the active bipartite group — the same
    /// arithmetic as `protocol::apply_churn_event`, evaluated against
    /// the mirror, which holds exactly the live cores' `hat_self`s.
    fn join(&mut self, w: usize) {
        assert!(!self.active[w], "join while present");
        let d = self.problem.d;
        let mut warm = vec![0.0; d];
        let mut count = 0usize;
        for (j, &on) in self.active.iter().enumerate() {
            if j != w && on && self.topo.group(j) == self.topo.group(w) {
                for (acc, v) in warm.iter_mut().zip(&self.mirror[j]) {
                    *acc += *v;
                }
                count += 1;
            }
        }
        if count > 0 {
            let inv = 1.0 / count as f64;
            warm.iter_mut().for_each(|v| *v *= inv);
        } else {
            warm.copy_from_slice(&self.mirror[w]);
        }
        if let Some(c) = self.conns[w].as_mut() {
            let h = c.begin(kind::REJOIN);
            wire::put_f64s(c.payload(), &warm);
            let peers: Vec<usize> = self
                .topo
                .neighbors(w)
                .iter()
                .copied()
                .filter(|&m| self.active[m])
                .collect();
            wire::put_u64(c.payload(), peers.len() as u64);
            for m in peers {
                wire::put_u64(c.payload(), m as u64);
                wire::put_f64s(c.payload(), &self.mirror[m]);
            }
            c.end(h);
        }
        for &m in self.topo.neighbors(w) {
            if !self.active[m] {
                continue;
            }
            if let Some(c) = self.conns[m].as_mut() {
                let h = c.begin(kind::ATTACH);
                wire::put_u64(c.payload(), w as u64);
                wire::put_f64s(c.payload(), &warm);
                c.end(h);
            }
        }
        self.mirror[w].copy_from_slice(&warm);
        self.parked[w] = None;
        self.active[w] = true;
    }

    /// Zero worker `w`'s staleness counters — worker-level and, for
    /// multi-block problems, every block age (churn boundary semantics
    /// shared with the in-process engines).
    fn reset_stale(&mut self, w: usize) {
        self.stale[w] = 0;
        let nb = self.problem.blocks.count();
        if nb > 1 {
            self.block_stale[w * nb..(w + 1) * nb].fill(0);
        }
    }

    /// Start-of-iteration boundary: disconnect-driven leaves, reconnect
    /// joins, then the scheduled churn events — each one logged, each
    /// one mirrored to the fleet over the wire.
    fn apply_boundary_churn(&mut self) {
        let mut changed = false;
        let mut leaves = std::mem::take(&mut self.pending_leave);
        leaves.sort_unstable();
        leaves.dedup();
        for w in leaves {
            if !self.active[w] {
                continue;
            }
            self.leave(w);
            self.reset_stale(w);
            changed = true;
            if let Some(rec) = &mut self.recorder {
                rec.worker_leave(self.iter, w);
            }
        }
        let mut joins = std::mem::take(&mut self.pending_join);
        joins.sort_unstable();
        joins.dedup();
        for w in joins {
            if self.active[w] || self.conns[w].is_none() {
                continue;
            }
            self.join(w);
            self.reset_stale(w);
            changed = true;
            if let Some(rec) = &mut self.recorder {
                rec.worker_join(self.iter, w);
            }
        }
        if let Some(churn) = &self.opts.churn {
            let events = churn.events_at(self.iter).to_vec();
            for e in &events {
                match e.kind {
                    ChurnKind::Leave => self.leave(e.worker),
                    ChurnKind::Join => self.join(e.worker),
                }
                self.reset_stale(e.worker);
                changed = true;
                if let Some(rec) = &mut self.recorder {
                    match e.kind {
                        ChurnKind::Leave => rec.worker_leave(self.iter, e.worker),
                        ChurnKind::Join => rec.worker_join(self.iter, e.worker),
                    }
                }
            }
        }
        if changed {
            self.refresh_live_groups();
        }
    }

    fn step(&mut self) {
        assert!(self.started, "step before wait_for_fleet");
        self.apply_boundary_churn();
        let k_plus_1 = self.iter + 1;
        let groups = std::mem::take(&mut self.live_groups);
        for group in &groups {
            self.run_phase(group, k_plus_1);
        }
        self.live_groups = groups;
        // dual update: every connected worker runs it iff it has
        // neighbors — for active workers that is exactly the in-process
        // `active && !neighbors.is_empty()` condition (detached workers
        // have no neighbors by construction)
        for c in self.conns.iter_mut().flatten() {
            c.push_frame(kind::DUAL);
        }
        self.flush_all();
        self.iter += 1;
        if self.iter % self.opts.record_every == 0 {
            self.record();
        }
    }

    fn record(&mut self) {
        // losses + thetas from every connected worker (inactive ones
        // report their frozen state — same values the in-process record
        // reads from frozen cores); departed workers contribute the
        // loss/theta parked at their goodbye
        for (ready, conn) in self.report_ready.iter_mut().zip(self.conns.iter_mut()) {
            *ready = false;
            if let Some(c) = conn {
                c.push_frame(kind::REPORT_REQ);
            }
        }
        self.flush_all();
        self.pump_until("record reports", |s| {
            s.report_ready
                .iter()
                .enumerate()
                .all(|(i, &ready)| ready || s.conns[i].is_none())
        });
        let obj: f64 = self.losses.iter().sum();
        let mut consensus: f64 = 0.0;
        for &(h, t) in self.topo.edges() {
            if !(self.active[h] && self.active[t]) {
                continue;
            }
            let diff: f64 = self.thetas[h]
                .iter()
                .zip(&self.thetas[t])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            consensus = consensus.max(diff);
        }
        let log = self.medium.log();
        let point = TracePoint {
            iteration: self.iter,
            loss_gap: (obj - self.problem.f_star).abs(),
            consensus_gap: consensus,
            cum_rounds: log.rounds(),
            cum_bits: log.total_bits,
            cum_energy_j: log.total_energy_j,
        };
        self.trace.push(point);
        if let Some(rec) = &mut self.recorder {
            rec.record(&point, log, self.medium.sim_time_s());
        }
    }

    // ---- persistence ---------------------------------------------------

    fn snapshot_state(&mut self) -> RunState {
        for (slot, conn) in self.exports.iter_mut().zip(self.conns.iter_mut()) {
            *slot = None;
            if let Some(c) = conn {
                c.push_frame(kind::EXPORT_REQ);
            }
        }
        self.flush_all();
        self.pump_until("checkpoint exports", |s| {
            s.exports
                .iter()
                .enumerate()
                .all(|(i, e)| e.is_some() || s.conns[i].is_none())
        });
        let cores: Vec<CoreState> = (0..self.conns.len())
            .map(|i| match self.exports[i].take() {
                Some(cs) => cs,
                None => self.parked[i].clone().unwrap_or_else(|| {
                    panic!("cannot checkpoint: worker {i} vanished without exporting state")
                }),
            })
            .collect();
        let log = self.medium.log();
        RunState {
            iteration: self.iter,
            cores,
            medium: MediumState {
                rounds: log.rounds(),
                total_bits: log.total_bits,
                total_energy_j: log.total_energy_j,
                sim_time_s: self.medium.sim_time_s(),
                link: self.medium.link_state(),
            },
            trace: self.trace.clone(),
            active: self.active.clone(),
            stale: self.stale.clone(),
            block_stale: self.block_stale.clone(),
            block_bits: log.block_bits.clone(),
        }
    }

    fn restore_state(&mut self, s: &RunState) {
        let n = self.topo.n();
        assert_eq!(s.cores.len(), n, "checkpoint is for a different worker count");
        assert_eq!(s.active.len(), n, "checkpoint dynamic section size");
        assert_eq!(s.stale.len(), n, "checkpoint dynamic section size");
        assert!(
            !self.started && self.conns.iter().all(|c| c.is_none()),
            "restore must happen before the fleet registers"
        );
        // the transport takes the checkpoint's membership as-is (it may
        // include disconnect-driven leaves no schedule describes);
        // workers rebuild their structure from the bitmap in `Welcome`
        self.active.clone_from(&s.active);
        self.stale.copy_from_slice(&s.stale);
        if s.block_stale.is_empty() {
            self.block_stale.fill(0);
        } else {
            assert_eq!(
                s.block_stale.len(),
                self.block_stale.len(),
                "checkpoint block staleness size"
            );
            self.block_stale.copy_from_slice(&s.block_stale);
        }
        for (i, cs) in s.cores.iter().enumerate() {
            self.mirror[i].copy_from_slice(&cs.hat_self);
            self.thetas[i].copy_from_slice(&cs.theta);
            self.parked[i] = Some(cs.clone());
            if !s.active[i] {
                // a departed worker may never reconnect; its frozen loss
                // must survive the restore for the record sums
                self.losses[i] = self.frozen_loss(i, cs);
            }
        }
        self.medium.restore(
            s.medium.rounds,
            s.medium.total_bits,
            s.medium.total_energy_j,
            s.medium.sim_time_s,
            &s.medium.link,
        );
        self.medium.restore_block_bits(s.block_bits.clone());
        self.trace = s.trace.clone();
        self.iter = s.iteration;
        self.refresh_live_groups();
        if let Some(rec) = &mut self.recorder {
            rec.rebase(s.iteration);
        }
    }

    /// Loss of a frozen (departed) worker, recomputed server-side: build
    /// its core, shape it to the parked (detached) structure, import and
    /// evaluate — the same arithmetic the worker itself ran.
    fn frozen_loss(&self, i: usize, state: &CoreState) -> f64 {
        let cfg = ProtocolConfig {
            backend: Backend::Native,
            artifacts_dir: None,
            incremental: self.opts.incremental,
            seed: self.opts.seed,
        };
        let mut core = build_core_at(&self.problem, &self.topo, &self.spec, &cfg, i);
        let nbrs: Vec<usize> = core.neighbors().to_vec();
        let keep = state.hat_nbrs.len();
        if keep == 0 {
            for m in nbrs {
                core.detach_neighbor(m);
            }
        } else {
            assert_eq!(keep, nbrs.len(), "parked state for worker {i} has unexpected degree");
        }
        core.import_state(state);
        core.loss()
    }

    fn shutdown(&mut self) {
        self.closing = true;
        for c in self.conns.iter_mut().flatten() {
            c.push_frame(kind::SHUTDOWN);
        }
        let deadline = Instant::now() + BARRIER_TIMEOUT;
        loop {
            let progress = self.pump_io();
            let pending = self
                .conns
                .iter()
                .flatten()
                .any(|c| c.has_pending_send() && !c.peer_closed());
            if !pending || Instant::now() > deadline {
                break;
            }
            if !progress {
                std::thread::sleep(IDLE_BACKOFF);
            }
        }
        for c in self.conns.iter_mut() {
            *c = None;
        }
    }
}

fn parse_hello(body: &[u8]) -> Result<usize, String> {
    let (&k, rest) = body.split_first().ok_or("empty frame")?;
    if k != kind::HELLO {
        return Err(format!("expected hello, got frame kind {k}"));
    }
    let mut r = wire::Reader::new(rest);
    let id = r.u64("worker id")? as usize;
    Ok(id)
}
