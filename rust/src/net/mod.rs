//! TCP transport for the coordinator — networked runs on std sockets.
//!
//! The in-process engines ([`crate::algs::Run`], the sharded
//! [`crate::coordinator::Coordinator`]) are the reference; this module
//! runs the *same* protocol over localhost or a real network with no
//! runtime dependencies beyond `std::net`:
//!
//! * [`server::NetCoordinator`] — the coordinator side.  A nonblocking
//!   `TcpListener` plus a poll-style readiness loop multiplexes every
//!   worker connection on one thread.  It owns the shared medium (the
//!   paper's bit/energy accounting), the link model, the trace and the
//!   event log; per-round broadcasts are coalesced into one batched
//!   write per connection.
//! * [`client::run_worker`] — the worker side.  One process hosts one or
//!   more worker ids, each driving a [`crate::protocol::WorkerCore`]
//!   built locally via [`crate::protocol::build_core_at`] from the
//!   manifest the server ships at registration.
//!
//! Framing is `[u32 LE length][u8 kind][payload]`
//! ([`crate::coordinator::message::MAX_FRAME_LEN`]-bounded); kinds and
//! payload primitives live in [`wire`].  Both ends keep persistent
//! per-connection buffers, so the round hot path is allocation-free
//! after warm-up.
//!
//! Determinism: the server resolves every phase in ascending worker
//! order against the same medium and RNG state as the in-process
//! engines, so a networked run is bit-for-bit identical to
//! `Coordinator` — trace, bits, energy and checkpoint bytes
//! (`tests/net_equivalence.rs` locks this across all six algorithm
//! variants).  A worker disconnect maps onto the churn machinery: the
//! run degrades exactly like a scheduled `leave`, and a reconnect
//! warm-starts like a scheduled `join`.

pub mod client;
pub mod conn;
pub mod server;
pub mod wire;

use crate::algs::{AlgSpec, Problem};
use crate::config::{ExperimentManifest, ModelSpec};
use crate::data;
use crate::graph::{gen, Topology};

/// Build the (problem, topology, algorithm) triple a manifest describes.
///
/// Both ends of the transport call this — the server from its local
/// manifest, the worker from the TOML shipped in the `Welcome` frame —
/// and must agree bit-for-bit, so the construction mirrors the CLI
/// exactly: explicit topology spec, else chain for `gadmm`, else the
/// seeded random bipartite graph.
pub fn build_session(m: &ExperimentManifest) -> Result<(Problem, Topology, AlgSpec), String> {
    let e = &m.experiment;
    let spec = AlgSpec::parse(&m.alg, e.tau0, e.xi, e.omega, e.bits0)?
        .with_bits_split(e.bits_split.clone());
    spec.validate()?;
    let topo = match e.topology {
        Some(spec) => gen::build(&spec, e.workers, e.seed)?.topology,
        None if m.alg == "gadmm" => Topology::chain(e.workers),
        None => Topology::random_bipartite(e.workers, e.connectivity, e.seed),
    };
    let ds = data::load(e.dataset, e.seed);
    let problem =
        Problem::with_model(&ds, &topo, e.rho, e.mu0, e.seed, e.model.unwrap_or(ModelSpec::Glm))?;
    Ok((problem, topo, spec))
}
