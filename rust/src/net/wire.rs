//! Frame-body primitives of the TCP transport.
//!
//! Every frame is `[u32 LE length][u8 kind][payload]` — the length layer
//! lives in [`crate::coordinator::message`] (`begin_frame` /
//! `parse_frame`, bounded by `MAX_FRAME_LEN`); this module defines the
//! kind bytes and the little-endian payload primitives both ends share.
//! Writers append into persistent per-connection send buffers and the
//! reader borrows the receive buffer in place, so the round hot path
//! allocates nothing after connection warm-up.

/// Frame kinds.  Workers send the low range, the server the high range —
/// a stray frame in the wrong direction fails loudly instead of aliasing.
pub mod kind {
    /// Worker -> server: register worker `id` on this connection.
    pub const HELLO: u8 = 1;
    /// Worker -> server: phase reply — transmit decision plus the
    /// optimistically encoded pending payload (trailing bytes).
    pub const CANDIDATE: u8 = 2;
    /// Worker -> server: loss + theta for a trace record.
    pub const REPORT: u8 = 3;
    /// Worker -> server: checkpoint export (`CoreState` bytes trail).
    pub const EXPORT: u8 = 4;
    /// Worker -> server: clean departure — loss + post-detach state.
    pub const GOODBYE: u8 = 5;

    /// Server -> worker: registration accepted; resume iteration,
    /// membership bitmap, optional `CoreState`, manifest TOML (trailing).
    pub const WELCOME: u8 = 16;
    /// Server -> worker: run one phase (`k_plus_1`, force flag).
    pub const PHASE: u8 = 17;
    /// Server -> worker: the pending broadcast landed — commit it.
    pub const COMMIT: u8 = 18;
    /// Server -> worker: the pending broadcast was lost — abort it.
    pub const ABORT: u8 = 19;
    /// Server -> worker: a neighbor's committed payload (trailing bytes).
    pub const DELIVER: u8 = 20;
    /// Server -> worker: end of iteration — run the dual update.
    pub const DUAL: u8 = 21;
    /// Server -> worker: send a `REPORT`.
    pub const REPORT_REQ: u8 = 22;
    /// Server -> worker: send an `EXPORT`.
    pub const EXPORT_REQ: u8 = 23;
    /// Server -> worker: detach the named departed peer.
    pub const DETACH: u8 = 24;
    /// Server -> worker: scheduled leave — detach every neighbor.
    pub const DETACH_ALL: u8 = 25;
    /// Server -> worker: attach a rejoining peer with its warm hat.
    pub const ATTACH: u8 = 26;
    /// Server -> worker: warm-start a rejoin and attach the listed peers.
    pub const REJOIN: u8 = 27;
    /// Server -> worker: run complete — close cleanly.
    pub const SHUTDOWN: u8 = 28;
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

/// Length-prefixed `f64` vector (bit-exact, like the checkpoint codec).
pub fn put_f64s(out: &mut Vec<u8>, v: &[f64]) {
    put_u64(out, v.len() as u64);
    out.reserve(v.len() * 8);
    for &x in v {
        put_f64(out, x);
    }
}

/// Cursor over one frame body with descriptive errors — a malformed
/// frame drops the connection, it never panics the engine.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "frame truncated reading {what}: wanted {n} bytes at offset {} of {}",
                self.pos,
                self.buf.len()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self, what: &str) -> Result<u8, String> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u64(&mut self, what: &str) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().expect("8 bytes")))
    }

    pub fn f64(&mut self, what: &str) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// Decode a `put_f64s` vector **in place** into `slot` (dimension
    /// must match — the transport never resizes model buffers).
    pub fn f64s_into(&mut self, slot: &mut [f64], what: &str) -> Result<(), String> {
        let n = self.u64(what)? as usize;
        if n != slot.len() {
            return Err(format!("{what}: dimension {n} does not match expected {}", slot.len()));
        }
        for v in slot.iter_mut() {
            *v = self.f64(what)?;
        }
        Ok(())
    }

    /// Remaining bytes of the frame (trailing payload fields).
    pub fn rest(self) -> &'a [u8] {
        &self.buf[self.pos..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip() {
        let mut buf = Vec::new();
        put_u64(&mut buf, 42);
        put_f64(&mut buf, -0.0);
        put_f64s(&mut buf, &[1.5, f64::MIN_POSITIVE]);
        buf.extend_from_slice(b"tail");
        let mut r = Reader::new(&buf);
        assert_eq!(r.u64("a").unwrap(), 42);
        assert_eq!(r.f64("b").unwrap().to_bits(), (-0.0f64).to_bits());
        let mut slot = [0.0; 2];
        r.f64s_into(&mut slot, "v").unwrap();
        assert_eq!(slot[0], 1.5);
        assert_eq!(slot[1].to_bits(), f64::MIN_POSITIVE.to_bits());
        assert_eq!(r.rest(), b"tail");
    }

    #[test]
    fn reader_errors_are_descriptive() {
        let mut r = Reader::new(&[1, 2]);
        let err = r.u64("field-x").unwrap_err();
        assert!(err.contains("field-x"), "{err}");
        let mut buf = Vec::new();
        put_f64s(&mut buf, &[1.0; 3]);
        let mut r = Reader::new(&buf);
        let mut slot = [0.0; 2];
        assert!(r.f64s_into(&mut slot, "hat").unwrap_err().contains("dimension"));
    }
}
