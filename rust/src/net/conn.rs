//! Nonblocking framed connection with persistent buffers.
//!
//! One [`Conn`] wraps one `TcpStream` set to nonblocking + `TCP_NODELAY`.
//! Reads drain into a persistent receive buffer and frames are parsed in
//! place via [`crate::coordinator::message::parse_frame`]; writes append
//! into a persistent send buffer and [`Conn::flush`] resumes partial
//! writes across readiness passes.  Both buffers keep their capacity, so
//! after the first few rounds the transport hot path allocates nothing.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::ops::Range;

use crate::coordinator::message::{begin_frame, finish_frame, parse_frame};

pub struct Conn {
    stream: TcpStream,
    recv: Vec<u8>,
    recv_pos: usize,
    send: Vec<u8>,
    send_pos: usize,
    /// Offset of the open frame header while one is being built.
    open_frame: Option<usize>,
    peer_closed: bool,
}

impl Conn {
    pub fn new(stream: TcpStream) -> std::io::Result<Conn> {
        stream.set_nodelay(true)?;
        stream.set_nonblocking(true)?;
        Ok(Conn {
            stream,
            recv: Vec::new(),
            recv_pos: 0,
            send: Vec::new(),
            send_pos: 0,
            open_frame: None,
            peer_closed: false,
        })
    }

    /// True once the peer has closed its end (a later `pump_recv` saw EOF).
    pub fn peer_closed(&self) -> bool {
        self.peer_closed
    }

    /// Drain whatever the socket has ready into the receive buffer.
    /// Returns `true` if any bytes arrived.
    pub fn pump_recv(&mut self) -> Result<bool, String> {
        let mut chunk = [0u8; 16 * 1024];
        let mut got = false;
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.peer_closed = true;
                    return Ok(got);
                }
                Ok(k) => {
                    self.recv.extend_from_slice(&chunk[..k]);
                    got = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(got),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == ErrorKind::ConnectionReset => {
                    self.peer_closed = true;
                    return Ok(got);
                }
                Err(e) => return Err(format!("socket read: {e}")),
            }
        }
    }

    /// Byte range of the next complete frame body inside the receive
    /// buffer, if one has fully arrived.  When no complete frame is
    /// buffered the consumed prefix is compacted away (`copy_within`, no
    /// reallocation) so the buffer cannot grow without bound.
    pub fn frame_range(&mut self) -> Result<Option<Range<usize>>, String> {
        match parse_frame(&self.recv[self.recv_pos..])? {
            Some(body) => {
                let start = self.recv_pos + 4;
                Ok(Some(start..start + body.len()))
            }
            None => {
                if self.recv_pos > 0 {
                    self.recv.copy_within(self.recv_pos.., 0);
                    let left = self.recv.len() - self.recv_pos;
                    self.recv.truncate(left);
                    self.recv_pos = 0;
                }
                Ok(None)
            }
        }
    }

    /// Borrow frame bytes returned by [`Conn::frame_range`].
    pub fn bytes(&self, r: Range<usize>) -> &[u8] {
        &self.recv[r]
    }

    /// Mark the frame at `r` as consumed.
    pub fn consume(&mut self, r: &Range<usize>) {
        self.recv_pos = r.end;
    }

    /// Open a frame of the given kind in the send buffer.  Append the
    /// payload through [`Conn::payload`], then seal with [`Conn::end`].
    pub fn begin(&mut self, kind: u8) -> usize {
        assert!(self.open_frame.is_none(), "nested frame write");
        let h = begin_frame(&mut self.send);
        self.send.push(kind);
        self.open_frame = Some(h);
        h
    }

    /// The send buffer, positioned inside the currently open frame.
    pub fn payload(&mut self) -> &mut Vec<u8> {
        debug_assert!(self.open_frame.is_some(), "payload outside an open frame");
        &mut self.send
    }

    pub fn end(&mut self, h: usize) {
        assert_eq!(self.open_frame.take(), Some(h), "mismatched frame seal");
        finish_frame(&mut self.send, h);
    }

    /// Convenience: queue a payload-free frame.
    pub fn push_frame(&mut self, kind: u8) {
        let h = self.begin(kind);
        self.end(h);
    }

    /// True when queued bytes are waiting to go out.
    pub fn has_pending_send(&self) -> bool {
        self.send_pos < self.send.len()
    }

    /// Write as much queued data as the socket accepts right now;
    /// `Ok(true)` once everything queued has been flushed.
    pub fn flush(&mut self) -> Result<bool, String> {
        debug_assert!(self.open_frame.is_none(), "flush with an unsealed frame");
        while self.send_pos < self.send.len() {
            match self.stream.write(&self.send[self.send_pos..]) {
                Ok(0) => return Err("socket write: connection closed".into()),
                Ok(k) => self.send_pos += k,
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(format!("socket write: {e}")),
            }
        }
        self.send.clear();
        self.send_pos = 0;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn pair() -> (Conn, Conn) {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let a = TcpStream::connect(addr).expect("connect");
        let (b, _) = listener.accept().expect("accept");
        (Conn::new(a).expect("conn a"), Conn::new(b).expect("conn b"))
    }

    fn pump_until_frame(c: &mut Conn) -> Vec<u8> {
        for _ in 0..10_000 {
            c.pump_recv().expect("recv");
            if let Some(r) = c.frame_range().expect("parse") {
                let body = c.bytes(r.clone()).to_vec();
                c.consume(&r);
                return body;
            }
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
        panic!("no frame arrived");
    }

    #[test]
    fn frames_cross_a_loopback_socket() {
        let (mut a, mut b) = pair();
        let h = a.begin(7);
        a.payload().extend_from_slice(b"hello");
        a.end(h);
        a.push_frame(9);
        while !a.flush().expect("flush") {}
        let first = pump_until_frame(&mut b);
        assert_eq!(first, b"\x07hello");
        let second = pump_until_frame(&mut b);
        assert_eq!(second, b"\x09");
    }

    #[test]
    fn eof_is_reported_without_error() {
        let (a, mut b) = pair();
        drop(a);
        for _ in 0..10_000 {
            b.pump_recv().expect("recv");
            if b.peer_closed() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
        panic!("peer close not observed");
    }
}
