//! # cq-ggadmm — Communication-Efficient Decentralized Learning
//!
//! A production-grade reproduction of *"Communication Efficient Distributed
//! Learning with Censored, Quantized, and Generalized Group ADMM"*
//! (Ben Issaid, Elgabli, Park, Bennis — 2020).
//!
//! The crate implements the full algorithm family of the paper —
//! **GGADMM** (group ADMM over arbitrary bipartite+connected topologies),
//! **C-GGADMM** (per-link censoring), **CQ-GGADMM** (censoring + adaptive
//! stochastic quantization) — together with the paper's baselines
//! (**C-ADMM** of Liu et al. 2019, chain **GADMM**, decentralized gradient
//! descent), the wireless communication-energy model of §7, and a bench
//! harness that regenerates every figure of the evaluation.
//!
//! ## Architecture (three layers, Python never on the hot path)
//!
//! * **Layer 3 (this crate)** — the decentralized runtime: topology
//!   management, head/tail phase scheduling, censoring gates, quantized
//!   payload codec, the shared per-worker protocol core ([`protocol`])
//!   with its three drivers (the sequential simulator in [`algs`], the
//!   sharded coordinator in [`coordinator`], and the TCP transport in
//!   [`net`]), pluggable link models ([`comm`]), metrics and the
//!   experiment harness.
//! * **Layer 2 (JAX, build time)** — per-worker subproblem solvers lowered
//!   AOT to HLO text in `artifacts/` (see `python/compile/model.py`).
//! * **Layer 1 (Pallas, build time)** — the compute hot-spot kernels the
//!   L2 solvers call (`python/compile/kernels/`).
//!
//! [`runtime`] loads the HLO artifacts through PJRT (`xla` crate, behind
//! the off-by-default `pjrt` cargo feature — the default build is
//! dependency-free) and executes them on the per-iteration hot path;
//! [`solver`] provides the bit-identical native Rust implementation used
//! for differential testing and as a fallback when no artifact matches a
//! shape.
//!
//! ## Perf contract
//!
//! The iteration hot path ([`algs::Run::step`]) is allocation-free after
//! construction and **censoring-aware**: solvers update in place via
//! [`solver::SubproblemSolver::update_into`] (the logistic Newton loop is
//! fully fused — persistent gradient/Hessian/factor scratch, O(s) Armijo
//! trials from cached margins), neighbor sums and dual increments are
//! maintained incrementally so censored/dropped rounds skip their
//! O(deg * d) rebuilds entirely, and shard data is shared behind `Arc`
//! rather than copied per worker.  The opt-in `threads > 1` fan-out runs
//! on a persistent barrier-synchronized [`parallel::WorkerPool`] built
//! once per run (no per-phase thread spawns).  The dense kernels under
//! [`linalg`] dispatch through a runtime-selected **kernel tier**
//! (AVX2+FMA when detected, scalar reference otherwise — see
//! [`linalg::KernelTier`]; override with `CQ_KERNEL_TIER` or
//! `--kernel-tier`) and pool their Gram/GEMM/Cholesky trailing updates
//! across cores above size thresholds, bit-identically to serial.
//! Per-step O(d^2)/O(s) solver arithmetic is intrinsic to the math.
//! `cargo bench --bench bench_hotpath` tracks the numbers and emits
//! machine-readable `BENCH_hotpath.json` (see EXPERIMENTS.md §Perf);
//! CI gates the run against `BENCH_baseline.json` via
//! `tools/bench_diff.py`.

pub mod algs;
pub mod analysis;
pub mod censor;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod graph;
pub mod io;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod parallel;
pub mod param;
pub mod protocol;
pub mod quant;
pub mod runtime;
pub mod solver;
pub mod testing;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::algs::{AlgSpec, Problem, Run, RunOptions, Schedule};
    pub use crate::censor::CensorConfig;
    pub use crate::data::Dataset;
    pub use crate::graph::Topology;
    pub use crate::linalg::Mat;
    pub use crate::metrics::Trace;
    pub use crate::quant::QuantConfig;
    pub use crate::util::rng::Pcg64;
}
