"""Layer-2 JAX model: the per-worker subproblem solvers of CQ-GGADMM.

Each public function here is an AOT entry point (see ``aot.py``): it is
jitted, calls the Layer-1 Pallas kernels for its compute hot-spot, and is
lowered once to HLO text that the Rust runtime executes via PJRT on the
per-iteration hot path.  Python never runs at request time.

Worker-n subproblem (paper eqs. (21)/(22); identical form for head/tail):

    theta_n^{k+1} = argmin_theta  f_n(theta)
                    + <theta, alpha_n - rho * sum_{m in N_n} theta_hat_m>
                    + (rho d_n / 2) ||theta||^2

* linear regression  f_n = 1/2 ||X_n theta - y_n||^2 — closed form:
  ``linear_setup`` assembles the Gram system once, Rust inverts
  ``A = X^T X + rho d_n I`` once (native Cholesky), and every iteration runs
  the fused ``linear_update`` artifact.
* logistic regression f_n = (1/s) sum log(1+exp(-y x theta)) + mu0/2 ||.||^2
  — ``logistic_newton`` runs a fixed budget of damped Newton steps, each
  assembling (g, H) with the Pallas kernel and solving H delta = g by CG.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import (
    ROW_BLOCK,
    fused_local_update,
    gram,
    logistic_grad_hess,
    stochastic_quantize,
)

# Fixed iteration budgets baked into the AOT artifacts (recorded in the
# manifest).  Newton on these strongly-convex subproblems converges to fp32
# precision well within this budget; CG solves the (d, d) system essentially
# exactly for the paper's d <= 50.
NEWTON_STEPS = 8
CG_ITERS = 64


def pad_rows(x, y, mask=None, row_block=ROW_BLOCK):
    """Zero-pad the sample dimension to a multiple of ``row_block``.

    Returns ``(x_pad, y_pad, mask_pad)``; padded rows carry mask 0 and are
    exact no-ops in both workloads (zero rows contribute nothing to the
    Gram system; the logistic kernel masks them).
    """
    s = x.shape[0]
    pad = (-s) % row_block
    if mask is None:
        mask = jnp.ones((s,), x.dtype)
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad))
        mask = jnp.pad(mask, (0, pad))
    return x, y, mask


# --------------------------------------------------------------------------
# Linear regression
# --------------------------------------------------------------------------


@jax.jit
def linear_setup(x, y):
    """One-time Gram assembly: ``(X^T X, X^T y)`` via the Pallas kernel."""
    xtx, xty = gram(x, y)
    return (xtx, xty)


@jax.jit
def linear_update(a_inv, xty, alpha, nbr_sum, rho):
    """Per-iteration closed-form primal update (fused Pallas rhs+matvec).

    ``rho`` has shape (1,) so the artifact signature is all-array.
    """
    return (fused_local_update(a_inv, xty, alpha, nbr_sum, rho),)


@jax.jit
def linear_loss(x, y, theta):
    """Local objective 1/2 ||X theta - y||^2 (padded rows are zeros)."""
    r = x @ theta - y
    return (0.5 * jnp.dot(r, r),)


# --------------------------------------------------------------------------
# Logistic regression
# --------------------------------------------------------------------------


def _cg_solve(hmv, b, iters):
    """Conjugate gradient on the SPD system ``H delta = b`` (matrix-free)."""

    def body(_, state):
        xk, rk, pk, rs = state
        hp = hmv(pk)
        denom = jnp.dot(pk, hp)
        alpha = rs / jnp.maximum(denom, 1e-30)
        xk = xk + alpha * pk
        rk = rk - alpha * hp
        rs_new = jnp.dot(rk, rk)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        pk = rk + beta * pk
        return (xk, rk, pk, rs_new)

    x0 = jnp.zeros_like(b)
    state = (x0, b, b, jnp.dot(b, b))
    state = jax.lax.fori_loop(0, iters, body, state)
    return state[0]


@functools.partial(jax.jit, static_argnames=("newton_steps", "cg_iters"))
def logistic_newton(
    x,
    y,
    mask,
    inv_count,
    mu0,
    rho_dn,
    lin,
    theta0,
    *,
    newton_steps=NEWTON_STEPS,
    cg_iters=CG_ITERS,
):
    """Solve the logistic GGADMM subproblem with fixed-budget Newton + CG.

    Arguments (all f32 arrays; scalars have shape (1,)):
      x (s, d), y (s,) in {-1, +1}, mask (s,) in {0, 1},
      inv_count = 1/s_real, mu0 = ridge, rho_dn = rho * d_n,
      lin (d,) = alpha_n - rho * sum_{m in N_n} theta_hat_m,
      theta0 (d,) = warm start (previous iterate).
    """
    inv_s = inv_count[0]
    reg = mu0[0] + rho_dn[0]

    def newton_body(_, theta):
        g_data, h_data = logistic_grad_hess(x, y, mask, theta)
        grad = inv_s * g_data + mu0[0] * theta + lin + rho_dn[0] * theta

        def hmv(v):
            return inv_s * jnp.dot(h_data, v) + reg * v

        delta = _cg_solve(hmv, grad, cg_iters)
        return theta - delta

    theta = jax.lax.fori_loop(0, newton_steps, newton_body, theta0)
    return (theta,)


@jax.jit
def logistic_loss(x, y, mask, inv_count, mu0, theta):
    """Local objective (1/s) sum log(1+exp(-y x theta)) + mu0/2 ||theta||^2."""
    z = y * (x @ theta)
    # log1p(exp(-z)) computed stably; masked rows contribute 0.
    val = jnp.where(mask > 0, jnp.logaddexp(0.0, -z), 0.0)
    loss = inv_count[0] * jnp.sum(val) + 0.5 * mu0[0] * jnp.dot(theta, theta)
    return (loss,)


# --------------------------------------------------------------------------
# Quantizer (codec oracle — the Rust hot path has a native twin that is
# differential-tested against this artifact)
# --------------------------------------------------------------------------


@jax.jit
def quantize(v, q_prev, r, levels, u):
    """Stochastic quantization of paper §5; see kernels/quantize.py."""
    q, recon = stochastic_quantize(v, q_prev, r, levels, u)
    return (q, recon)
