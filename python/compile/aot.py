"""AOT compiler: lower every Layer-2 entry point to HLO text artifacts.

Run once at build time (``make artifacts``).  For each entry point and each
shape the experiment suite needs, this lowers the jitted function with
example ``ShapeDtypeStruct`` arguments, converts the StableHLO module to an
``XlaComputation`` and dumps its **HLO text** into ``artifacts/``, plus a
``manifest.json`` describing every artifact's I/O signature so the Rust
runtime can marshal ``Literal``s without guessing.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits ``HloModuleProto``s with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ROW_BLOCK

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Experiment shape inventory.  (s_pad, d) per workload:
#   synth-linear   : 1200 samples / 24 workers = 50 rows, d=50 -> (56, 50)
#   Body Fat       :  252 samples / 18 workers = 14 rows, d=14 -> (16, 14)
#   synth-logistic : 1200 samples / 24 workers = 50 rows, d=50 -> (56, 50)
#   Derm           :  358 samples / 18 workers <= 20 rows, d=34 -> (24, 34)
# plus a tiny (8, 4) shape exercised by the Rust integration tests.
LINEAR_SHAPES = [(56, 50), (16, 14), (8, 4)]
LOGISTIC_SHAPES = [(56, 50), (24, 34), (8, 4)]
QUANT_DIMS = [50, 34, 14, 4]


def entry_points(linear_shapes, logistic_shapes, quant_dims):
    """Yield (name, lowered, input_specs, output_names, meta) tuples."""
    out = []
    for s, d in linear_shapes:
        ins = [("x", (s, d)), ("y", (s,))]
        out.append(
            (
                f"linear_setup_{s}x{d}",
                "linear_setup",
                jax.jit(model.linear_setup).lower(spec(s, d), spec(s)),
                ins,
                ["xtx", "xty"],
                {},
            )
        )
        out.append(
            (
                f"linear_loss_{s}x{d}",
                "linear_loss",
                jax.jit(model.linear_loss).lower(spec(s, d), spec(s), spec(d)),
                ins + [("theta", (d,))],
                ["loss"],
                {},
            )
        )
    for d in sorted({d for _, d in linear_shapes}):
        out.append(
            (
                f"linear_update_{d}",
                "linear_update",
                jax.jit(model.linear_update).lower(
                    spec(d, d), spec(d), spec(d), spec(d), spec(1)
                ),
                [
                    ("a_inv", (d, d)),
                    ("xty", (d,)),
                    ("alpha", (d,)),
                    ("nbr_sum", (d,)),
                    ("rho", (1,)),
                ],
                ["theta"],
                {},
            )
        )
    for s, d in logistic_shapes:
        out.append(
            (
                f"logistic_newton_{s}x{d}",
                "logistic_newton",
                jax.jit(
                    lambda x, y, m, ic, mu, rd, lin, t0: model.logistic_newton(
                        x, y, m, ic, mu, rd, lin, t0
                    )
                ).lower(
                    spec(s, d),
                    spec(s),
                    spec(s),
                    spec(1),
                    spec(1),
                    spec(1),
                    spec(d),
                    spec(d),
                ),
                [
                    ("x", (s, d)),
                    ("y", (s,)),
                    ("mask", (s,)),
                    ("inv_count", (1,)),
                    ("mu0", (1,)),
                    ("rho_dn", (1,)),
                    ("lin", (d,)),
                    ("theta0", (d,)),
                ],
                ["theta"],
                {"newton_steps": model.NEWTON_STEPS, "cg_iters": model.CG_ITERS},
            )
        )
        out.append(
            (
                f"logistic_loss_{s}x{d}",
                "logistic_loss",
                jax.jit(model.logistic_loss).lower(
                    spec(s, d), spec(s), spec(s), spec(1), spec(1), spec(d)
                ),
                [
                    ("x", (s, d)),
                    ("y", (s,)),
                    ("mask", (s,)),
                    ("inv_count", (1,)),
                    ("mu0", (1,)),
                    ("theta", (d,)),
                ],
                ["loss"],
                {},
            )
        )
    for d in quant_dims:
        out.append(
            (
                f"quantize_{d}",
                "quantize",
                jax.jit(model.quantize).lower(
                    spec(d), spec(d), spec(1), spec(1), spec(d)
                ),
                [
                    ("v", (d,)),
                    ("q_prev", (d,)),
                    ("r", (1,)),
                    ("levels", (1,)),
                    ("u", (d,)),
                ],
                ["q", "recon"],
                {},
            )
        )
    return out


def parse_pairs(text):
    """Parse '56x50,16x14' into [(56, 50), (16, 14)]."""
    pairs = []
    for tok in text.split(","):
        a, b = tok.strip().split("x")
        pairs.append((int(a), int(b)))
    return pairs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="make-target sentinel path; artifacts land beside it")
    ap.add_argument("--linear-shapes", default=None,
                    help="override linear (s,d) set, e.g. '56x50,16x14'")
    ap.add_argument("--logistic-shapes", default=None)
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)

    linear_shapes = (
        parse_pairs(args.linear_shapes) if args.linear_shapes else LINEAR_SHAPES
    )
    logistic_shapes = (
        parse_pairs(args.logistic_shapes) if args.logistic_shapes else LOGISTIC_SHAPES
    )

    manifest = {
        "format": "hlo-text",
        "dtype": "f32",
        "row_block": ROW_BLOCK,
        "artifacts": [],
    }
    total = 0
    for name, entry, lowered, ins, outs, meta in entry_points(
        linear_shapes, logistic_shapes, QUANT_DIMS
    ):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "entry": entry,
                "file": fname,
                "inputs": [{"name": n, "shape": list(s)} for n, s in ins],
                "outputs": outs,
                "meta": meta,
            }
        )
        total += len(text)
        print(f"  {fname}: {len(text)} chars")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # The make-target sentinel: a trivial valid HLO program whose mtime
    # marks the artifact build.  (Real entry points live in *.hlo.txt above.)
    lowered = jax.jit(lambda x: (x + 1.0,)).lower(spec(2))
    with open(args.out, "w") as f:
        f.write(to_hlo_text(lowered))
    print(
        f"wrote {len(manifest['artifacts'])} artifacts ({total} chars) "
        f"+ manifest.json to {out_dir}"
    )


if __name__ == "__main__":
    main()
