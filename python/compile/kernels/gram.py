"""Fused Gram-system assembly kernel: ``(X^T X, X^T y)`` in one pass.

This is the setup-time hot-spot of the linear-regression workload: each
worker assembles its normal-equation system once, after which every ADMM
iteration is a cheap fused rhs+matvec (see ``update.py``).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the sample dimension is
tiled into ``ROW_BLOCK``-row blocks streamed HBM->VMEM by the grid; each
grid step performs one ``(d, bs) @ (bs, d)`` MXU contraction and accumulates
into the VMEM-resident ``(d, d)`` output block, which every grid step maps
to the same output tile (classic revisiting-accumulator pattern).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step.  8 sublanes is the fp32 TPU tile height; the jnp.dot
# below then contracts (d, 8) @ (8, d) per step.  All artifact shapes pad
# the sample count to a multiple of this.
ROW_BLOCK = 8


def _gram_kernel(x_ref, y_ref, xtx_ref, xty_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        xtx_ref[...] = jnp.zeros_like(xtx_ref)
        xty_ref[...] = jnp.zeros_like(xty_ref)

    xb = x_ref[...]  # (ROW_BLOCK, d) block in VMEM
    yb = y_ref[...]  # (ROW_BLOCK,)
    # MXU contraction; accumulate in fp32.
    xtx_ref[...] += jnp.dot(xb.T, xb, preferred_element_type=jnp.float32)
    xty_ref[...] += jnp.dot(xb.T, yb, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("row_block",))
def gram(x, y, *, row_block=ROW_BLOCK):
    """Return ``(X^T X, X^T y)`` for ``x: (s, d)``, ``y: (s,)``.

    ``s`` must be a multiple of ``row_block`` (callers zero-pad; zero rows
    are exact no-ops for the Gram system).
    """
    s, d = x.shape
    if s % row_block != 0:
        raise ValueError(f"sample count {s} not a multiple of {row_block}")
    grid = (s // row_block,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
            pl.BlockSpec((row_block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, d), x.dtype),
            jax.ShapeDtypeStruct((d,), x.dtype),
        ],
        interpret=True,
    )(x, y)
