"""Fused logistic gradient + Gauss-Newton Hessian assembly kernel.

Per-iteration hot-spot of the logistic workload: every Newton step of every
worker's primal update assembles

  g = sum_i mask_i * (-y_i p_i) x_i,         p_i = sigmoid(-y_i x_i^T theta)
  H = sum_i mask_i * p_i (1 - p_i) x_i x_i^T

in a single pass over the local data.  The ``1/s`` scaling, the ridge term
and the ADMM penalty are added by the Layer-2 model.

TPU mapping: grid over ``ROW_BLOCK``-row sample blocks; ``theta`` and the
``(d,)``/``(d, d)`` accumulators live in VMEM across the whole grid (their
index maps are constant), each step performing two MXU contractions.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gram import ROW_BLOCK


def _logistic_kernel(x_ref, y_ref, mask_ref, theta_ref, g_ref, h_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = x_ref[...]          # (bs, d)
    yb = y_ref[...]          # (bs,)
    mb = mask_ref[...]       # (bs,)
    theta = theta_ref[...]   # (d,)

    z = yb * jnp.dot(xb, theta, preferred_element_type=jnp.float32)
    # sigmoid(-z), masked; exp is VPU work, contractions below are MXU.
    p = jnp.where(mb > 0, 1.0 / (1.0 + jnp.exp(z)), 0.0)
    g_ref[...] += jnp.dot(xb.T, -yb * p, preferred_element_type=jnp.float32)
    w = p * (1.0 - p)
    xw = xb * w[:, None]
    h_ref[...] += jnp.dot(xw.T, xb, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("row_block",))
def logistic_grad_hess(x, y, mask, theta, *, row_block=ROW_BLOCK):
    """Return masked ``(g, H)`` data terms for ``x: (s, d)``.

    ``s`` must be a multiple of ``row_block``; padded rows carry mask 0.
    """
    s, d = x.shape
    if s % row_block != 0:
        raise ValueError(f"sample count {s} not a multiple of {row_block}")
    grid = (s // row_block,)
    return pl.pallas_call(
        _logistic_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
            pl.BlockSpec((row_block,), lambda i: (i,)),
            pl.BlockSpec((row_block,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), x.dtype),
            jax.ShapeDtypeStruct((d, d), x.dtype),
        ],
        interpret=True,
    )(x, y, mask, theta)
