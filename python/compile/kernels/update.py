"""Fused GGADMM linear-regression primal update: rhs assembly + matvec.

Per-iteration hot path of the linear workload.  One kernel invocation
computes

  theta = A^{-1} (X^T y - alpha + rho * nbr_sum)

where ``A^{-1} = (X^T X + rho d_n I)^{-1}`` is precomputed once at setup.
Fusing the vector assembly with the matvec keeps the whole update a single
VMEM-resident block: for d <= 128 the ``(d, d)`` operand is one MXU tile.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(a_inv_ref, xty_ref, alpha_ref, nbr_ref, rho_ref, out_ref):
    rhs = xty_ref[...] - alpha_ref[...] + rho_ref[0] * nbr_ref[...]
    out_ref[...] = jnp.dot(a_inv_ref[...], rhs, preferred_element_type=jnp.float32)


@jax.jit
def fused_local_update(a_inv, xty, alpha, nbr_sum, rho):
    """theta = a_inv @ (xty - alpha + rho * nbr_sum); ``rho`` shape (1,)."""
    d = xty.shape[0]
    return pl.pallas_call(
        _update_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), xty.dtype),
        interpret=True,
    )(a_inv, xty, alpha, nbr_sum, rho)
