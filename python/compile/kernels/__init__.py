"""Layer-1 Pallas kernels for the CQ-GGADMM compute hot-spots.

Every kernel is written for TPU semantics (grid over the sample dimension,
VMEM-resident blocks, MXU-friendly fp32 ``jnp.dot`` accumulation) but is run
with ``interpret=True`` so the AOT-lowered HLO executes on the CPU PJRT
client used by the Rust runtime.  ``ref.py`` holds the pure-jnp oracles the
pytest suite checks against.
"""

from .gram import gram, ROW_BLOCK
from .logistic import logistic_grad_hess
from .update import fused_local_update
from .quantize import stochastic_quantize

__all__ = [
    "gram",
    "logistic_grad_hess",
    "fused_local_update",
    "stochastic_quantize",
    "ROW_BLOCK",
]
