"""Pure-jnp correctness oracles for the Pallas kernels.

These are deliberately written in the most direct dense form; the pytest
suite (and hypothesis sweeps) assert the Pallas kernels match them to fp32
tolerance over randomized shapes and values.
"""

import jax.numpy as jnp


def gram_ref(x, y):
    """Gram system of the per-worker linear-regression subproblem.

    Returns ``(X^T X, X^T y)`` for ``x: (s, d)``, ``y: (s,)``.
    """
    return x.T @ x, x.T @ y


def logistic_grad_hess_ref(x, y, mask, theta):
    """Masked logistic loss gradient and Gauss-Newton Hessian *data terms*.

    For margins ``z_i = y_i x_i^T theta`` and ``p_i = sigmoid(-z_i)``:

      g = sum_i mask_i * (-y_i p_i) x_i          (shape (d,))
      H = sum_i mask_i * p_i (1 - p_i) x_i x_i^T (shape (d, d))

    The ``1/s`` normalization and the regularizer / penalty terms are added
    by the Layer-2 model, not the kernel.
    """
    z = y * (x @ theta)
    p = jnp.where(mask > 0, 1.0 / (1.0 + jnp.exp(z)), 0.0)
    g = x.T @ (-y * p)
    w = p * (1.0 - p)
    h = (x * w[:, None]).T @ x
    return g, h


def fused_local_update_ref(a_inv, xty, alpha, nbr_sum, rho):
    """Closed-form GGADMM primal update for linear regression.

    theta = A^{-1} (X^T y - alpha + rho * sum_{m in N_n} theta_hat_m)
    with A = X^T X + rho d_n I factored/inverted once at setup time.
    """
    rhs = xty - alpha + rho * nbr_sum
    return a_inv @ rhs


def stochastic_quantize_ref(v, q_prev, r, levels, u):
    """Stochastic quantizer of paper eqs. (14)-(17), given uniforms ``u``.

    c = (v - q_prev + r) / delta, delta = 2 r / (levels - 1)
    q = floor(c) + [u < frac(c)]     (unbiased probabilistic rounding)
    recon = q_prev + delta * q - r   (eq. (20))

    Returns ``(q, recon)``; ``q`` is kept in f32 so the whole artifact
    stays a single-dtype HLO program (the Rust codec re-integerizes).
    """
    delta = 2.0 * r / (levels - 1.0)
    c = (v - q_prev + r) / delta
    low = jnp.floor(c)
    frac = c - low
    q = low + (u < frac).astype(v.dtype)
    q = jnp.clip(q, 0.0, levels - 1.0)
    recon = q_prev + delta * q - r
    return q, recon
