"""Stochastic quantization kernel (paper §5, eqs. (14)-(17), (20)).

Element-wise unbiased probabilistic rounding of the difference between the
current model and the previously-quantized model, given externally supplied
uniforms (Pallas kernels are deterministic; the RNG lives in the caller so
the Rust and Python paths can share a stream).

Pure VPU work — included both as the quantization oracle the Rust codec is
differential-tested against and as the L1 demonstration that the whole
CQ-GGADMM per-link pipeline lowers through Pallas.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(v_ref, qprev_ref, r_ref, levels_ref, u_ref, q_ref, recon_ref):
    r = r_ref[0]
    levels = levels_ref[0]
    delta = 2.0 * r / (levels - 1.0)
    c = (v_ref[...] - qprev_ref[...] + r) / delta
    low = jnp.floor(c)
    frac = c - low
    q = low + (u_ref[...] < frac).astype(c.dtype)
    q = jnp.clip(q, 0.0, levels - 1.0)
    q_ref[...] = q
    recon_ref[...] = qprev_ref[...] + delta * q - r


@jax.jit
def stochastic_quantize(v, q_prev, r, levels, u):
    """Quantize ``v`` against ``q_prev``; ``r``/``levels`` are shape (1,).

    Returns ``(q, recon)`` — the integer code (as f32) and the dequantized
    reconstruction ``\\hat Q`` of eq. (20).
    """
    d = v.shape[0]
    return pl.pallas_call(
        _quantize_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((d,), v.dtype),
            jax.ShapeDtypeStruct((d,), v.dtype),
        ],
        interpret=True,
    )(v, q_prev, r, levels, u)
