"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and values; every kernel must match ``ref.py`` to
fp32 tolerance for all of them.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import (
    ROW_BLOCK,
    fused_local_update,
    gram,
    logistic_grad_hess,
    stochastic_quantize,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


def make_xy(seed, blocks, d):
    r = rng(seed)
    s = blocks * ROW_BLOCK
    x = r.normal(size=(s, d)).astype(np.float32)
    y = r.normal(size=(s,)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


# ---------------------------------------------------------------- gram ----


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 8),
    d=st.integers(1, 40),
)
def test_gram_matches_ref(seed, blocks, d):
    x, y = make_xy(seed, blocks, d)
    xtx, xty = gram(x, y)
    rxtx, rxty = ref.gram_ref(x, y)
    np.testing.assert_allclose(xtx, rxtx, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(xty, rxty, rtol=1e-5, atol=1e-4)


def test_gram_zero_row_padding_is_noop():
    x, y = make_xy(0, 2, 5)
    xp = jnp.concatenate([x, jnp.zeros((ROW_BLOCK, 5), jnp.float32)])
    yp = jnp.concatenate([y, jnp.zeros((ROW_BLOCK,), jnp.float32)])
    a, b = gram(x, y)
    ap, bp = gram(xp, yp)
    np.testing.assert_allclose(a, ap, rtol=1e-6)
    np.testing.assert_allclose(b, bp, rtol=1e-6)


def test_gram_rejects_unpadded_rows():
    x = jnp.zeros((ROW_BLOCK + 1, 3), jnp.float32)
    y = jnp.zeros((ROW_BLOCK + 1,), jnp.float32)
    with pytest.raises(ValueError):
        gram(x, y)


def test_gram_symmetry():
    x, y = make_xy(7, 4, 12)
    xtx, _ = gram(x, y)
    np.testing.assert_allclose(xtx, xtx.T, rtol=1e-6)


# ------------------------------------------------------------ logistic ----


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 6),
    d=st.integers(1, 30),
)
def test_logistic_grad_hess_matches_ref(seed, blocks, d):
    x, _ = make_xy(seed, blocks, d)
    r = rng(seed + 1)
    s = blocks * ROW_BLOCK
    y = jnp.asarray(r.choice([-1.0, 1.0], size=s).astype(np.float32))
    mask = jnp.asarray((r.uniform(size=s) < 0.8).astype(np.float32))
    theta = jnp.asarray(r.normal(size=d).astype(np.float32))
    g, h = logistic_grad_hess(x, y, mask, theta)
    rg, rh = ref.logistic_grad_hess_ref(x, y, mask, theta)
    np.testing.assert_allclose(g, rg, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h, rh, rtol=1e-4, atol=1e-4)


def test_logistic_masked_rows_do_not_contribute():
    x, _ = make_xy(3, 2, 6)
    s = 2 * ROW_BLOCK
    r = rng(3)
    y = jnp.asarray(r.choice([-1.0, 1.0], size=s).astype(np.float32))
    theta = jnp.asarray(r.normal(size=6).astype(np.float32))
    full = jnp.ones((s,), jnp.float32)
    half = jnp.concatenate(
        [jnp.ones((ROW_BLOCK,), jnp.float32), jnp.zeros((ROW_BLOCK,), jnp.float32)]
    )
    g_half, h_half = logistic_grad_hess(x, y, half, theta)
    g_sub, h_sub = logistic_grad_hess(
        x[:ROW_BLOCK], y[:ROW_BLOCK], full[:ROW_BLOCK], theta
    )
    np.testing.assert_allclose(g_half, g_sub, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h_half, h_sub, rtol=1e-5, atol=1e-5)


def test_logistic_hessian_psd():
    x, _ = make_xy(11, 3, 8)
    s = 3 * ROW_BLOCK
    r = rng(11)
    y = jnp.asarray(r.choice([-1.0, 1.0], size=s).astype(np.float32))
    mask = jnp.ones((s,), jnp.float32)
    theta = jnp.asarray(r.normal(size=8).astype(np.float32))
    _, h = logistic_grad_hess(x, y, mask, theta)
    eig = np.linalg.eigvalsh(np.asarray(h, dtype=np.float64))
    assert eig.min() >= -1e-5


# -------------------------------------------------------------- update ----


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 64))
def test_fused_local_update_matches_ref(seed, d):
    r = rng(seed)
    a_inv = jnp.asarray(r.normal(size=(d, d)).astype(np.float32))
    xty = jnp.asarray(r.normal(size=d).astype(np.float32))
    alpha = jnp.asarray(r.normal(size=d).astype(np.float32))
    nbr = jnp.asarray(r.normal(size=d).astype(np.float32))
    rho = jnp.asarray([abs(r.normal()) + 0.1], dtype=jnp.float32)
    got = fused_local_update(a_inv, xty, alpha, nbr, rho)
    want = ref.fused_local_update_ref(a_inv, xty, alpha, nbr, rho[0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ quantize ----


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.integers(1, 64),
    bits=st.integers(2, 12),
)
def test_quantize_matches_ref(seed, d, bits):
    r = rng(seed)
    v = jnp.asarray(r.normal(size=d).astype(np.float32))
    q_prev = jnp.asarray(r.normal(size=d).astype(np.float32))
    rad = float(np.max(np.abs(np.asarray(v - q_prev)))) + 1e-3
    levels = jnp.asarray([float(2**bits)], dtype=jnp.float32)
    radius = jnp.asarray([rad], dtype=jnp.float32)
    u = jnp.asarray(r.uniform(size=d).astype(np.float32))
    q, recon = stochastic_quantize(v, q_prev, radius, levels, u)
    rq, rrecon = ref.stochastic_quantize_ref(v, q_prev, radius[0], levels[0], u)
    np.testing.assert_allclose(q, rq, rtol=0, atol=0)
    np.testing.assert_allclose(recon, rrecon, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), bits=st.integers(2, 10))
def test_quantize_error_within_step(seed, bits):
    """|recon - v| <= delta for every coordinate (paper §5)."""
    d = 32
    r = rng(seed)
    v = jnp.asarray(r.normal(size=d).astype(np.float32))
    q_prev = jnp.asarray(r.normal(size=d).astype(np.float32))
    rad = float(np.max(np.abs(np.asarray(v - q_prev)))) + 1e-3
    levels = float(2**bits)
    delta = 2.0 * rad / (levels - 1.0)
    u = jnp.asarray(r.uniform(size=d).astype(np.float32))
    _, recon = stochastic_quantize(
        v,
        q_prev,
        jnp.asarray([rad], jnp.float32),
        jnp.asarray([levels], jnp.float32),
        u,
    )
    err = np.abs(np.asarray(recon - v))
    assert err.max() <= delta * (1 + 1e-3)


def test_quantize_unbiased_statistically():
    """Monte-Carlo check of eq. (16): E[recon] == v."""
    d = 16
    r = rng(123)
    v = jnp.asarray(r.normal(size=d).astype(np.float32))
    q_prev = jnp.zeros((d,), jnp.float32)
    rad = float(np.max(np.abs(np.asarray(v)))) + 1e-3
    levels = jnp.asarray([8.0], jnp.float32)  # 3 bits -> 8 grid points
    radius = jnp.asarray([rad], jnp.float32)
    trials = 3000
    acc = np.zeros(d, np.float64)
    for t in range(trials):
        u = jnp.asarray(r.uniform(size=d).astype(np.float32))
        _, recon = stochastic_quantize(v, q_prev, radius, levels, u)
        acc += np.asarray(recon, np.float64)
    mean = acc / trials
    delta = 2.0 * rad / 7.0
    # standard error of a bounded-by-delta variable over `trials` draws
    np.testing.assert_allclose(mean, np.asarray(v), atol=4 * delta / np.sqrt(trials))
