"""Layer-2 model tests: the AOT entry points solve their subproblems."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ROW_BLOCK

SETTINGS = dict(max_examples=15, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


def test_pad_rows_multiple_of_block():
    r = rng(0)
    x = jnp.asarray(r.normal(size=(13, 5)).astype(np.float32))
    y = jnp.asarray(r.normal(size=13).astype(np.float32))
    xp, yp, mp = model.pad_rows(x, y)
    assert xp.shape[0] % ROW_BLOCK == 0
    assert xp.shape[0] == yp.shape[0] == mp.shape[0]
    assert float(mp.sum()) == 13.0
    np.testing.assert_allclose(xp[:13], x)


def test_pad_rows_already_aligned_is_identity():
    r = rng(1)
    x = jnp.asarray(r.normal(size=(ROW_BLOCK, 3)).astype(np.float32))
    y = jnp.asarray(r.normal(size=ROW_BLOCK).astype(np.float32))
    xp, yp, _ = model.pad_rows(x, y)
    assert xp.shape == x.shape and yp.shape == y.shape


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(2, 20))
def test_linear_update_solves_normal_equations(seed, d):
    """linear_setup + native inverse + linear_update == argmin of eq. (21)."""
    r = rng(seed)
    s = 4 * ROW_BLOCK
    x = jnp.asarray(r.normal(size=(s, d)).astype(np.float32))
    y = jnp.asarray(r.normal(size=s).astype(np.float32))
    alpha = jnp.asarray(r.normal(size=d).astype(np.float32))
    nbr = jnp.asarray(r.normal(size=d).astype(np.float32))
    rho, dn = 1.0, 3.0

    (xtx, xty) = model.linear_setup(x, y)
    a = np.asarray(xtx, np.float64) + rho * dn * np.eye(d)
    a_inv = jnp.asarray(np.linalg.inv(a).astype(np.float32))
    (theta,) = model.linear_update(
        a_inv, xty, alpha, rho * dn / rho * nbr * 0 + nbr, jnp.asarray([rho], jnp.float32)
    )

    # gradient of the subproblem at theta must vanish:
    #   X^T(X theta - y) + alpha - rho*nbr + rho*dn*theta = 0
    g = (
        np.asarray(xtx) @ np.asarray(theta)
        - np.asarray(xty)
        + np.asarray(alpha)
        - rho * np.asarray(nbr)
        + rho * dn * np.asarray(theta)
    )
    scale = max(1.0, float(np.abs(np.asarray(xty)).max()))
    assert np.abs(g).max() / scale < 5e-3


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(2, 12))
def test_logistic_newton_reaches_stationarity(seed, d):
    """The fixed Newton budget drives the subproblem gradient to ~0."""
    r = rng(seed)
    s = 3 * ROW_BLOCK
    x = jnp.asarray(r.normal(size=(s, d)).astype(np.float32))
    y = jnp.asarray(r.choice([-1.0, 1.0], size=s).astype(np.float32))
    mask = jnp.ones((s,), jnp.float32)
    mu0, rho_dn = 0.1, 2.0
    lin = jnp.asarray(0.1 * r.normal(size=d).astype(np.float32))
    theta0 = jnp.zeros((d,), jnp.float32)

    (theta,) = model.logistic_newton(
        x,
        y,
        mask,
        jnp.asarray([1.0 / s], jnp.float32),
        jnp.asarray([mu0], jnp.float32),
        jnp.asarray([rho_dn], jnp.float32),
        lin,
        theta0,
    )

    th = np.asarray(theta, np.float64)
    xs = np.asarray(x, np.float64)
    ys = np.asarray(y, np.float64)
    z = ys * (xs @ th)
    p = 1.0 / (1.0 + np.exp(z))
    grad = xs.T @ (-ys * p) / s + mu0 * th + np.asarray(lin) + rho_dn * th
    assert np.abs(grad).max() < 1e-3


def test_logistic_loss_matches_numpy():
    r = rng(5)
    s, d = 2 * ROW_BLOCK, 6
    x = jnp.asarray(r.normal(size=(s, d)).astype(np.float32))
    y = jnp.asarray(r.choice([-1.0, 1.0], size=s).astype(np.float32))
    mask = jnp.ones((s,), jnp.float32)
    theta = jnp.asarray(r.normal(size=d).astype(np.float32))
    mu0 = 0.05
    (loss,) = model.logistic_loss(
        x, y, mask,
        jnp.asarray([1.0 / s], jnp.float32),
        jnp.asarray([mu0], jnp.float32),
        theta,
    )
    z = np.asarray(y) * (np.asarray(x) @ np.asarray(theta))
    want = np.mean(np.logaddexp(0.0, -z)) + 0.5 * mu0 * np.sum(np.asarray(theta) ** 2)
    np.testing.assert_allclose(float(loss), want, rtol=1e-5)


def test_linear_loss_matches_numpy():
    r = rng(6)
    s, d = 2 * ROW_BLOCK, 5
    x = jnp.asarray(r.normal(size=(s, d)).astype(np.float32))
    y = jnp.asarray(r.normal(size=s).astype(np.float32))
    theta = jnp.asarray(r.normal(size=d).astype(np.float32))
    (loss,) = model.linear_loss(x, y, theta)
    res = np.asarray(x) @ np.asarray(theta) - np.asarray(y)
    np.testing.assert_allclose(float(loss), 0.5 * np.sum(res**2), rtol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(2, 16))
def test_cg_solve_matches_direct_solve(seed, d):
    """The in-graph CG solver reaches the direct solution on SPD systems."""
    r = rng(seed)
    b_mat = r.normal(size=(d, d))
    a = (b_mat.T @ b_mat + d * 0.3 * np.eye(d)).astype(np.float32)
    rhs = r.normal(size=d).astype(np.float32)

    def hmv(v):
        return jnp.asarray(a) @ v

    x = model._cg_solve(hmv, jnp.asarray(rhs), 2 * d)
    want = np.linalg.solve(a.astype(np.float64), rhs.astype(np.float64))
    np.testing.assert_allclose(np.asarray(x), want, rtol=5e-3, atol=5e-3)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), s=st.integers(1, 40))
def test_pad_rows_mask_preserved(seed, s):
    r = rng(seed)
    x = jnp.asarray(r.normal(size=(s, 3)).astype(np.float32))
    y = jnp.asarray(r.normal(size=s).astype(np.float32))
    mask = jnp.asarray((r.uniform(size=s) < 0.5).astype(np.float32))
    xp, yp, mp = model.pad_rows(x, y, mask)
    assert float(mp.sum()) == float(mask.sum())
    assert float(jnp.abs(xp[s:]).sum()) == 0.0
    assert float(jnp.abs(yp[s:]).sum()) == 0.0


def test_logistic_newton_warm_start_idempotent():
    """Re-solving from the solution must stay at the solution."""
    r = rng(9)
    s, d = 2 * ROW_BLOCK, 5
    x = jnp.asarray(r.normal(size=(s, d)).astype(np.float32))
    y = jnp.asarray(r.choice([-1.0, 1.0], size=s).astype(np.float32))
    mask = jnp.ones((s,), jnp.float32)
    args = (
        x, y, mask,
        jnp.asarray([1.0 / s], jnp.float32),
        jnp.asarray([0.1], jnp.float32),
        jnp.asarray([1.0], jnp.float32),
        jnp.asarray(0.1 * r.normal(size=d).astype(np.float32)),
    )
    (theta1,) = model.logistic_newton(*args, jnp.zeros((d,), jnp.float32))
    (theta2,) = model.logistic_newton(*args, theta1)
    np.testing.assert_allclose(theta1, theta2, rtol=1e-4, atol=1e-5)
