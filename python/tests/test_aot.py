"""AOT pipeline tests: HLO text emission + manifest integrity."""

import json
import os
import subprocess
import sys

import jax

from compile import aot, model


def test_to_hlo_text_smoke():
    lowered = jax.jit(model.linear_update).lower(
        aot.spec(4, 4), aot.spec(4), aot.spec(4), aot.spec(4), aot.spec(1)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_entry_point_inventory_covers_experiments():
    eps = aot.entry_points(aot.LINEAR_SHAPES, aot.LOGISTIC_SHAPES, aot.QUANT_DIMS)
    names = {e[0] for e in eps}
    # every experiment workload shape must be present
    for required in [
        "linear_setup_56x50",
        "linear_setup_16x14",
        "linear_update_50",
        "linear_update_14",
        "logistic_newton_56x50",
        "logistic_newton_24x34",
        "quantize_50",
        "quantize_34",
    ]:
        assert required in names, required


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "model.hlo.txt"
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out",
            str(out),
            "--linear-shapes",
            "8x4",
            "--logistic-shapes",
            "8x4",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert manifest["row_block"] == 8
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    assert "linear_setup_8x4" in by_name
    for art in manifest["artifacts"]:
        f = tmp_path / art["file"]
        assert f.exists()
        assert "HloModule" in f.read_text()[:200]
        assert art["inputs"] and art["outputs"]
